"""Serving runtime: batched prefill+decode with mARGOt QoS adaptation.

This is the UC2 (navigation) runtime shape: requests arrive with a prompt,
the server prefils then decodes N tokens; the woven knobs (precision
variant, decode budget, memoization on/off) are adapted by mARGOt against a
quality index + latency/cost constraints — reproducing the paper's
NQI-vs-cost trade-off (Figs. 17–19) in benchmarks/navigation_autotune.py.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies.resilience import (
    DEFAULT_POLICY,
    FaultError,
    NonFiniteLogits,
)
from repro.core.weaver import WovenProgram
from repro.distributed.fault import Watchdog
from repro.memo.table import MemoTable
from repro.monitor.examon import ExamonBroker, get_default_broker
from repro.monitor.sensors import apply_wrappers
from repro.nn.module import init_params
from repro.runtime.pages import (
    PagedCacheManager,
    PoolAuditor,
    PoolExhausted,
    cdiv,
    paged_compatible,
)
from repro.runtime.steps import (
    build_decode_step,
    build_paged_prefill_step,
    build_prefill_step,
    stack_request_caches,
)
from repro.versioning.libvc import LibVC


@dataclasses.dataclass
class ServerConfig:
    max_cache_len: int = 256
    decode_tokens: int = 8
    seed: int = 0
    # paged / continuous-batching serving (serve_continuous)
    page_size: int | None = None   # None: woven knob or 128 default
    pool_pages: int | None = None  # None: sized for full concurrency
    max_batch: int | None = None   # decode-batch cap (admission gate)
    prefix_sharing: bool = True    # map common prompt prefixes onto shared pages
    # speculative decoding (serve_continuous): tokens the draft model
    # proposes per verify round; None/0 falls back to the woven
    # "speculative_draft_len" knob, then to plain one-token decode
    draft_len: int | None = None
    # quantized page pool (serve_continuous): "int8" / "float8_e4m3fn" /
    # "float8_e5m2" stores pk/pv quantized with per-page-per-KV-head scale
    # sidecars; None falls back to the woven "flash_cache_dtype" knob, and
    # fp names (the tuner's accuracy-fallback arm) mean: keep the fp pool
    cache_dtype: str | None = None
    # resilience (serve_continuous): per-request SLO, bounded retry budget
    # around transient step faults, and PoolAuditor barriers; None falls
    # back to the woven "serve_resilience" policy (ResilienceAspect), then
    # to resilience.DEFAULT_POLICY
    deadline_s: float | None = None
    retries: int | None = None
    pool_audit: bool | None = None
    # QoS-adaptive streaming (serve_stream): tokens of a long admission
    # prefilled per decode wave (0/None: one-shot admission, unless a woven
    # QoS governor drives the knob), and per-request latency SLOs threaded
    # into the QoS policy (TTFT and per-token gap, seconds)
    prefill_chunk: int | None = None
    slo_ttft_s: float | None = None
    slo_tok_s: float | None = None


class Server:
    def __init__(self, woven: WovenProgram, cfg: ServerConfig, *, mesh=None,
                 margot=None, broker: ExamonBroker | None = None,
                 memo: MemoTable | None = None, draft: "Server | None" = None):
        self.woven = woven
        self.cfg = cfg
        # draft server for speculative decoding (registry `draft_for`
        # pairing, or any Server over the same vocab); None self-drafts
        # when a draft_len is requested
        self.draft = draft
        self.mesh = mesh
        self.margot = margot
        self.broker = broker or get_default_broker()
        self.memo = memo if memo is not None else woven.state.extra.get("memo_table")
        self.info: dict[str, Any] = {"task_name": woven.program.cfg.name, "knobs": {}}

        def build(kind):
            def builder(variant: str):
                v = None if variant == "__default__" else variant
                if kind == "prefill":
                    fn = build_prefill_step(self.woven, mesh=self.mesh, variant=v)
                elif kind == "probe":
                    # 1-token structure probe for the paged pool: a copied
                    # state pins cache_max_len=0 so the probe cache never
                    # materializes a dense max_len transient
                    fn = build_prefill_step(self.woven, mesh=self.mesh,
                                            variant=v, cache_max_len=0)
                elif kind == "paged_prefill":
                    # the pool cache is donated: the suffix scatter updates
                    # the page buffers in place, so admission's transient
                    # is bounded by the live prompt (one layer at a
                    # time), never a functional copy of the whole pool
                    # (admit_finish replaces the manager's handles with
                    # the step's outputs immediately after)
                    fn = build_paged_prefill_step(self.woven, mesh=self.mesh,
                                                  variant=v)
                    return jax.jit(fn, static_argnames=("prefix_len",),
                                   donate_argnums=(2,))
                elif kind == "rescore":
                    # NOT donated: the re-score step passes the pool
                    # buffers through untouched and its output is
                    # discarded — donating would invalidate the manager's
                    # live handles with nothing to replace them
                    fn = build_decode_step(self.woven, mesh=self.mesh,
                                           variant=v, rescore=True)
                else:
                    # the cache is donated on the decode hot path: the
                    # in-place scatter updates the (possibly pool-sized)
                    # buffers without a functional copy per token; every
                    # caller rebinds the cache to the step's output
                    # (serve/serve_batch loops, manager.absorb)
                    fn = build_decode_step(self.woven, mesh=self.mesh, variant=v)
                    return jax.jit(fn, donate_argnums=(2,))
                return jax.jit(fn)

            return LibVC(builder, error_strategy="fallback")

        self.prefill_vc = build("prefill")
        self.decode_vc = build("decode")
        self.probe_vc = build("probe")
        self.paged_prefill_vc = build("paged_prefill")
        self.rescore_vc = build("rescore")
        self.params = init_params(woven.program.model, jax.random.PRNGKey(cfg.seed),
                                  woven.state.policies)
        self.served = 0
        # latency histories are sliding windows (deques), not unbounded
        # lists: a long-running serve_stream session appends per wave, and
        # the feedback consumers (refine_kernel_tuner, the launchers'
        # percentile prints) only ever want recent observations anyway
        self.history_window = 4096
        self.latencies: deque[float] = deque(maxlen=self.history_window)
        self.decode_step_latencies: deque[float] = \
            deque(maxlen=self.history_window)  # serve_stream steps
        self._step_lat_by_batch: dict[int, deque[float]] = {}
        self._paged_sig = None  # last paged-decode signature served
        self._paged_dtype = None
        self.last_pool_stats: dict[str, Any] | None = None  # serve_continuous
        self.last_spec_stats: dict[str, Any] | None = None  # speculative serve
        self.last_fault_stats: dict[str, Any] | None = None  # resilience layer
        self.last_outcomes: list[dict[str, Any]] | None = None  # per request
        self.last_qos_stats: dict[str, Any] | None = None  # QoS governor
        self._last_admit_rescored = False  # last admission was a re-score
        self._verify_steps: dict[tuple, Callable] = {}  # (variant, S) -> fn

    def _variant(self) -> str | None:
        if self.margot is None:
            return None
        op = self.margot.update()
        self.info["knobs"].update(op.knobs)
        return op.knobs.get("variant") or op.knobs.get("precision_mix")

    def serve(self, tokens: np.ndarray, *, decode_tokens: int | None = None) -> np.ndarray:
        """tokens: (B, S) prompt -> (B, N) generated ids (greedy)."""
        n = decode_tokens or self.cfg.decode_tokens
        key = ("serve", tokens.tobytes(), n)
        if self.memo is not None and self.memo.running:
            hit, out = self.memo.lookup(key)
            if hit:
                return out
        t0 = time.perf_counter()
        variant = self._variant()
        state = self.woven.variant_state(
            None if variant in (None, "__default__") else variant
        )
        state.extra["cache_max_len"] = self.cfg.max_cache_len

        toks = jnp.asarray(tokens)
        B, S = toks.shape
        logits, cache = self.prefill_vc(variant, self.params, {"tokens": toks})
        outs = []
        pos = S
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        for _ in range(n):
            outs.append(tok)
            logits, cache = self.decode_vc(
                variant, self.params,
                {"tokens": tok, "positions": jnp.full((B, 1), pos, jnp.int32)},
                cache,
            )
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            pos += 1
        result = np.asarray(jnp.concatenate(outs, axis=1))
        dt = time.perf_counter() - t0
        self.latencies.append(dt)
        self.served += 1
        self.broker.publish(f"serve/latency/@host{jax.process_index()}", dt)
        if self.margot is not None:
            self.margot.observe("latency", dt)
        if self.memo is not None:
            self.memo.update(key, result)
        return result

    def serve_batch(self, prompts: list[np.ndarray], *,
                    decode_tokens: int | None = None) -> list[np.ndarray]:
        """Serve several requests — of *different* prompt lengths — as one
        batched decode: per-request prefill (each at its own length), caches
        stacked with per-request `index`, then a single decode loop at batch
        size B with per-request positions.  This is the layout the
        flash_decode kernel is built for: every request prunes its own live
        cache blocks through the scalar-prefetched index vector.

        Returns one (decode_tokens,) int array per request; greedy decode,
        bit-identical to serving each request alone.
        """
        n = decode_tokens or self.cfg.decode_tokens
        key = ("serve_batch", tuple(np.asarray(p).tobytes() for p in prompts), n)
        if self.memo is not None and self.memo.running:
            hit, out = self.memo.lookup(key)
            if hit:
                return out
        t0 = time.perf_counter()
        variant = self._variant()
        state = self.woven.variant_state(
            None if variant in (None, "__default__") else variant
        )
        state.extra["cache_max_len"] = self.cfg.max_cache_len

        caches, first_toks = [], []
        for p in prompts:
            toks = jnp.asarray(p, jnp.int32).reshape(1, -1)
            logits, cache = self.prefill_vc(variant, self.params,
                                            {"tokens": toks})
            caches.append(cache)
            first_toks.append(jnp.argmax(logits[0, -1], axis=-1))
        cache = stack_request_caches(self.woven.program.model, caches)

        B = len(prompts)
        pos = jnp.asarray([np.asarray(p).reshape(-1).shape[0] for p in prompts],
                          jnp.int32)
        tok = jnp.stack(first_toks).reshape(B, 1).astype(jnp.int32)
        outs = []
        for _ in range(n):
            outs.append(tok)
            logits, cache = self.decode_vc(
                variant, self.params,
                {"tokens": tok, "positions": pos[:, None]},
                cache,
            )
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            pos = pos + 1
        stacked = np.asarray(jnp.concatenate(outs, axis=1))
        result = [stacked[b] for b in range(B)]
        dt = time.perf_counter() - t0
        self.latencies.append(dt)
        self.served += B
        self.broker.publish(f"serve/latency/@host{jax.process_index()}", dt)
        if self.margot is not None:
            self.margot.observe("latency", dt)
        if self.memo is not None:
            self.memo.update(key, result)
        return result

    # -- paged pool + continuous batching -----------------------------------------

    def _page_size(self, state) -> int:
        from repro.kernels.flash_attention.ops import DEFAULT_PAGE_SIZE

        ps = self.cfg.page_size or state.extra.get("flash_page_size") \
            or DEFAULT_PAGE_SIZE
        return max(1, min(int(ps), self.cfg.max_cache_len))

    def _cache_dtype(self, state) -> str | None:
        """Resolved pool-quantization dtype name: explicit config wins,
        then the woven "flash_cache_dtype" knob.  Names outside CACHE_QMAX
        (the tuner's fp fallback arm, e.g. "float16") mean unquantized."""
        from repro.kernels.flash_attention.ops import CACHE_QMAX

        name = self.cfg.cache_dtype or state.extra.get("flash_cache_dtype")
        if name is None:
            return None
        name = str(name)
        return name if name in CACHE_QMAX else None

    def _resilience(self, state) -> dict[str, Any]:
        """Resolved recovery policy: resilience.DEFAULT_POLICY under the
        woven "serve_resilience" extra (ResilienceAspect), with explicit
        ServerConfig fields winning."""
        pol = dict(DEFAULT_POLICY)
        pol.update(state.extra.get("serve_resilience") or {})
        if self.cfg.deadline_s is not None:
            pol["deadline_s"] = float(self.cfg.deadline_s)
        if self.cfg.retries is not None:
            pol["retries"] = int(self.cfg.retries)
        if self.cfg.pool_audit is not None:
            pol["pool_audit"] = bool(self.cfg.pool_audit)
        return pol

    def _paged_admit(self, manager: PagedCacheManager, rid, prompt,
                     final_len: int, variant, inj=None) -> tuple[int, Any]:
        """Admit one request into the page pool, prefilling *directly into
        pool pages*, and return its first output token.

        The first admission runs a 1-token structure probe (cheap: the
        probe cache is unpadded) to learn the pool's group structure and
        dtypes; every admission then matches the prompt against the prefix
        index — full-page hits map shared physical pages and only the
        non-shared suffix is prefilled, a full-prompt hit skips prefill
        entirely and re-scores the last prompt token for its logits.
        Peak HBM per admission is O(live prompt tokens) for one layer
        at a time — only the non-shared suffix is *computed* — never the
        all-layer dense O(max_len) cache the packing path used to build.

        Returns (first token, fired "paged_prefill" fault spec or None) —
        the join point consults `inj` (a woven FaultInjector) right before
        the dispatch, and the first-token logits are checked finite: a
        NaN/Inf admission rolls its partial pool state back and raises
        `NonFiniteLogits` for the caller's structured-rejection path.
        """
        toks = jnp.asarray(prompt, jnp.int32).reshape(1, -1)
        toks_np = np.asarray(prompt, np.int64).reshape(-1)
        S = int(toks.shape[1])
        if not manager.has_structure:
            _, probe = self.probe_vc(variant, self.params,
                                     {"tokens": toks[:, :1]})
            if not paged_compatible(probe):
                raise ValueError(
                    "model cache is not paged-compatible (SSM/recurrent "
                    "state) — use serve_batch")
            ring = manager.window is not None and manager.window < S
            manager.init_structure(probe, ring=ring)
        shared_pages, shared_len = manager.match_prefix(toks_np)
        if shared_len >= S:
            # Long prompts compute their unshared first token through the
            # blocked online-softmax path (_attend_dense, S > 2*block);
            # the re-score step's one-shot decode softmax is a different
            # numeric family, so a full-prompt share would break shared ==
            # unshared bit-parity.  Trim the share to keep >= 1 suffix
            # token: the suffix prefill uses the same blocked path.
            state_extra = self.woven.variant_state(
                None if variant in (None, "__default__") else variant
            ).extra
            if S > 2 * int(state_extra.get("xla_attn_block", 1024)):
                ps = manager.page_size
                if shared_len % ps:      # drop the shared tail page
                    shared_pages = shared_pages[:-1]
                    shared_len = (S // ps) * ps
                if shared_len >= S:      # page-aligned prompt: drop a page
                    shared_pages = shared_pages[:-1]
                    shared_len -= ps
        self._last_admit_rescored = shared_len >= S
        # "paged_prefill" join point fires before any pool allocation, so a
        # raise-kind fault leaves nothing to roll back (the caller's abort
        # is then a no-op); a nan-kind poisons the logits below, driving
        # the same non-finite detector a real NaN would hit
        spec = inj.fire("paged_prefill", rid=rid) if inj is not None else None
        if shared_len >= S:
            manager.admit_shared(rid, toks_np, final_len=final_len,
                                 pages=shared_pages)
            view = manager.rescore_view(rid)
            logits, _ = self.rescore_vc(
                variant, self.params,
                {"tokens": toks[:, -1:],
                 "positions": jnp.full((1, 1), S - 1, jnp.int32)},
                view,
            )
        else:
            view, start = manager.admit_begin(
                rid, toks_np, final_len=final_len,
                shared_pages=shared_pages, shared_len=shared_len)
            pos = jnp.arange(start, S, dtype=jnp.int32)[None]
            logits, new_cache = self.paged_prefill_vc(
                variant, self.params,
                {"tokens": toks[:, start:], "positions": pos},
                view, prefix_len=start,
            )
            manager.admit_finish(rid, new_cache, toks_np)
        if spec is not None and spec.kind == "nan_logits":
            logits = jnp.full_like(logits, jnp.nan)
        if not bool(np.isfinite(float(
                jnp.max(logits[0, -1].astype(jnp.float32))))):
            manager.abort(rid)
            raise NonFiniteLogits(
                f"non-finite prefill logits for request {rid!r}")
        return int(jnp.argmax(logits[0, -1], axis=-1)), spec

    def _admit_grouped(self, manager: PagedCacheManager, rid, prompt,
                       final_len: int, first_tok: int) -> int | None:
        """Identical-prompt group admission: the member's full prompt is
        already pool-resident (its donor was just admitted through the
        re-score path), so it maps the donor's pages and reuses the donor's
        re-scored first token — the group shares ONE re-score step instead
        of running one per member.  Returns None (caller falls back to a
        full `_paged_admit`) if the prompt is no longer a full-prefix hit,
        e.g. the donor's pages were retired between the scan and now."""
        toks_np = np.asarray(prompt, np.int64).reshape(-1)
        pages, shared_len = manager.match_prefix(toks_np)
        if shared_len < toks_np.shape[0]:
            return None
        manager.admit_shared(rid, toks_np, final_len=final_len, pages=pages)
        return int(first_tok)

    def _verify_step(self, variant, draft_len: int) -> Callable:
        """Compiled widened-q verify step (S = draft_len + 1 q tokens per
        request), cached per (variant, draft_len); the cache is donated
        exactly like the plain decode step (manager.absorb rebinds)."""
        from repro.runtime.steps import build_verify_step

        key = (variant, draft_len)
        fn = self._verify_steps.get(key)
        if fn is None:
            v = None if variant in (None, "__default__") else variant
            fn = jax.jit(build_verify_step(self.woven, mesh=self.mesh,
                                           variant=v, draft_len=draft_len),
                         donate_argnums=(2,))
            self._verify_steps[key] = fn
        return fn

    def _qos_governor(self, state, qos, slo_ttft_s=None, slo_tok_s=None):
        """Resolve the serving QoS control plane: an explicit QoSGovernor
        (or policy dict) argument wins, then a woven `qos_governor`
        instance, then the woven `serve_qos` policy (QoSAspect); `False`
        forces it off.  ServerConfig / argument SLOs override the policy's
        before the governor is built."""
        from repro.runtime.qos import QoSGovernor

        if qos is False:
            return None
        if isinstance(qos, QoSGovernor):
            return qos
        if qos is None:
            gov = state.extra.get("qos_governor")
            if gov is not None:
                return gov
        pol = qos if isinstance(qos, dict) else state.extra.get("serve_qos")
        if pol is None:
            return None
        pol = dict(pol)
        if self.cfg.slo_ttft_s is not None:
            pol["slo_ttft_s"] = float(self.cfg.slo_ttft_s)
        if self.cfg.slo_tok_s is not None:
            pol["slo_tok_s"] = float(self.cfg.slo_tok_s)
        if slo_ttft_s is not None:
            pol["slo_ttft_s"] = float(slo_ttft_s)
        if slo_tok_s is not None:
            pol["slo_tok_s"] = float(slo_tok_s)
        if not pol.get("enabled", True):
            return None
        return QoSGovernor(pol, broker=self.broker)

    def _qos_enabled(self, qos) -> bool:
        """Cheap pre-check (no governor construction) used by the memo
        gate: would serve_stream run under a QoS control plane?"""
        if qos is False:
            return False
        if qos is not None:
            return True
        extra = self.woven.state.extra
        return extra.get("qos_governor") is not None \
            or extra.get("serve_qos") is not None

    def _paged_admit_chunked(self, manager: PagedCacheManager, rid, prompt,
                             final_len: int, variant, inj=None,
                             chunk: int = 0):
        """Chunked direct-to-pool admission: reserve the block table up
        front, then prefill page-aligned `chunk`-token slices of the
        non-shared suffix one call at a time, so a long admission spreads
        across decode waves instead of stalling the in-flight batch.

        Returns (tok, spec, cont):
          * tok set, cont None — the admission completed in one shot
            (full-prompt prefix hit, ring pool, blocked-softmax prompt, or
            a suffix that fits one chunk): delegated to `_paged_admit`;
          * tok None, cont a closure — call cont() once per wave: it
            returns {"tok": None, "resident": r, "chunk": c} after an
            interior chunk and {"tok": first_token, ...} once the final
            chunk ran (admit_finish absorbed the pool and registered the
            prefix).  A non-finite final chunk aborts the pool state and
            raises NonFiniteLogits exactly like the one-shot path.

        Parity: chunk boundaries are rounded down to page multiples (the
        shared prefix is page-aligned, so every pool page is written by
        exactly one dispatch and quantized first-write scales match a
        one-shot prefill), and each interior chunk runs the same widened-q
        suffix-over-prefix shape a prefix-sharing admission uses — already
        bit-identical to the dense one-shot prefill by the prefix-sharing
        parity suites.  Prompts on the blocked-softmax path (S > 2 *
        xla_attn_block) keep the one-shot prefill: their logits come from
        a different (blocked online-softmax) numeric family and splitting
        would change them.  Ring pools keep it too (eviction on write
        breaks the resident-prefix invariant between chunks).  The
        "paged_prefill" join point fires once, at reservation time,
        exactly like the one-shot path fires it before pool allocation.
        """
        toks = jnp.asarray(prompt, jnp.int32).reshape(1, -1)
        toks_np = np.asarray(prompt, np.int64).reshape(-1)
        S = int(toks.shape[1])
        if not manager.has_structure:
            _, probe = self.probe_vc(variant, self.params,
                                     {"tokens": toks[:, :1]})
            if not paged_compatible(probe):
                raise ValueError(
                    "model cache is not paged-compatible (SSM/recurrent "
                    "state) — use serve_batch")
            ring = manager.window is not None and manager.window < S
            manager.init_structure(probe, ring=ring)
        state_extra = self.woven.variant_state(
            None if variant in (None, "__default__") else variant
        ).extra
        blocked = S > 2 * int(state_extra.get("xla_attn_block", 1024))
        shared_pages, shared_len = manager.match_prefix(toks_np)
        ps = manager.page_size
        step = max(ps, (int(chunk) // ps) * ps)  # page-aligned, >= 1 page
        if (shared_len >= S or manager._ring_pool() or blocked
                or S - shared_len <= step):
            tok, spec = self._paged_admit(manager, rid, prompt, final_len,
                                          variant, inj=inj)
            return tok, spec, None
        self._last_admit_rescored = False
        spec = inj.fire("paged_prefill", rid=rid) if inj is not None else None
        _, start = manager.admit_begin(
            rid, toks_np, final_len=final_len,
            shared_pages=shared_pages, shared_len=shared_len)
        st = {"done": start}

        def cont() -> dict:
            done = st["done"]
            end = min(done + step, S)
            pos = jnp.arange(done, end, dtype=jnp.int32)[None]
            # the view is rebuilt per chunk, never cached: the dispatches
            # interleaved between chunks (decode steps, other admissions)
            # donate the pool arrays, so a held view would reference
            # deleted buffers — prefill_view rebinds to the live pools
            logits, new_cache = self.paged_prefill_vc(
                variant, self.params,
                {"tokens": toks[:, done:end], "positions": pos},
                manager.prefill_view(rid, done), prefix_len=done)
            if end < S:
                manager.absorb_prefill(rid, new_cache)
                st["done"] = end
                return {"tok": None, "resident": end, "chunk": end - done}
            manager.admit_finish(rid, new_cache, toks_np)
            lg = logits
            if spec is not None and spec.kind == "nan_logits":
                lg = jnp.full_like(lg, jnp.nan)
            if not bool(np.isfinite(float(
                    jnp.max(lg[0, -1].astype(jnp.float32))))):
                manager.abort(rid)
                raise NonFiniteLogits(
                    f"non-finite prefill logits for request {rid!r}")
            return {"tok": int(jnp.argmax(lg[0, -1], axis=-1)),
                    "resident": S, "chunk": end - done}

        return None, spec, cont

    @staticmethod
    def _draft_sync(draft_srv: "Server", dmanager: PagedCacheManager,
                    rids, active, outputs, lengths) -> None:
        """Restore the speculative lockstep invariant (draft resident
        length == target accepted length at round start) by replaying the
        target's emitted tokens through the draft cache.  Static-k serves
        never need this — rollback keeps both pools in sync — but a QoS
        governor that lowers draft_len to 0 for some waves leaves the
        draft behind by the tokens those plain waves emitted."""
        for r in rids:
            dlen = int(dmanager._meta[r]["length"])
            tgt = int(active[r]["pos"])
            while dlen < tgt:
                # slot p holds sequence token p; for p >= prompt length
                # that token is outputs[p - S]
                t = outputs[r][dlen - lengths[r]]
                dcache = dmanager.batch([r])
                _, dnew = draft_srv.decode_vc(
                    None, draft_srv.params,
                    {"tokens": jnp.asarray([[t]], jnp.int32),
                     "positions": jnp.asarray([[dlen]], jnp.int32)},
                    dcache)
                dmanager.absorb([r], dnew)
                dlen += 1

    def serve_continuous(self, prompts: list[np.ndarray], *,
                         decode_tokens: int | None = None,
                         page_size: int | None = None,
                         pool_pages: int | None = None,
                         max_batch: int | None = None,
                         prefix_sharing: bool | None = None,
                         draft_len: int | None = None,
                         draft: "Server | None" = None,
                         fault_injector=None,
                         deadline_s: float | None = None,
                         pool_audit: bool | None = None,
                         preemption=None,
                         prefill_chunk: int | None = None,
                         qos=None,
                         arrival_waves=None,
                         slo_ttft_s: float | None = None,
                         slo_tok_s: float | None = None,
                         on_event=None) -> list[np.ndarray]:
        """Continuous batching over a prefix-shared paged KV-cache pool.

        This is the thin compatibility wrapper over the `serve_stream`
        event loop: it handles the memo table (the stream engine never
        touches it), drains the per-token event stream (`on_event`
        receives each event dict when given), and returns the collected
        outputs — token-for-token identical to what the pre-stream
        monolith produced.

        Unlike `serve_batch` — which prefils everything up front, pads
        every request's cache to the same length and decodes the fixed
        batch in lockstep — this scheduler re-forms the decode batch every
        step: waiting requests are admitted as soon as the page pool can
        cover their worst-case growth (and a decode slot is free), each
        admitted request prefils its *non-shared prompt suffix* straight
        into freshly allocated pool pages (common prefixes map existing
        physical pages through the refcounted prefix index; the first
        write into a still-shared page splits it copy-on-write), and
        finished requests retire immediately, releasing their references
        for the next admission.  HBM scales with the *distinct live*
        tokens in flight — shared system prompts are stored once — and a
        long request never blocks a short one from entering mid-flight.

        Greedy decode, bit-identical per request to `serve` / `serve_batch`
        (the paged kernel streams the same live blocks in the same order —
        only the DMA source is page-table-indirected, and shared pages
        hold exactly the bytes an exclusive prefill would have written).
        Requires a cache family the pool can host (attention KV caches);
        SSM / recurrent state models raise — use `serve_batch`.

        Speculative decoding (`draft_len` = k > 0, explicit, from
        ServerConfig, or from the woven "speculative_draft_len" knob): a
        draft model (`draft`, the constructor's pairing, or this server
        itself) proposes k greedy tokens per round from its own page pool,
        and the target scores all k+1 positions in ONE widened-q verify
        step; the longest draft prefix matching the target's own argmax
        chain is accepted, the rejected tail rolls back via O(1)
        refcount truncation (no page copies).  Every emitted token is a
        target argmax, so the output is bit-identical to plain greedy —
        the draft only changes how many target steps it takes.  Ring
        pools fall back to plain decode (eviction breaks the widened
        mask); acceptance stats land in `last_spec_stats`.

        Resilience (woven ResilienceAspect, or the `fault_injector` /
        `deadline_s` / `pool_audit` arguments): faults are isolated
        per-request instead of killing the serve.  Failed or oversized
        admissions get structured `last_outcomes` entries; NaN/Inf logits
        quarantine only the victim (its pages retire, the batch re-forms);
        draft faults degrade speculation to plain decode; overdue requests
        retire with partial output and a `deadline_exceeded` marker;
        transient step faults retry with bounded backoff.  Survivors'
        tokens stay bit-identical to a fault-free serve, and
        `last_fault_stats` / ExaMon `serve/fault/*` topics record every
        event (zero events when nothing is woven).

        Graceful drain (`preemption`, a PreemptionHandler or anything with
        a `.pending` bool): once preemption is requested — SIGTERM on a
        real host, `request()` in tests — no new request is admitted;
        every in-flight request finishes its full decode normally, and the
        undrained waiting queue returns structured `drained` outcomes (so
        a fleet layer can hand those requests to a peer replica).
        """
        if not prompts:
            return []
        n = decode_tokens or self.cfg.decode_tokens
        k = draft_len if draft_len is not None else self.cfg.draft_len
        key = ("serve_continuous",
               tuple(np.asarray(p).tobytes() for p in prompts), n)
        if k:  # spec serves memoize separately (same tokens, different stats)
            key = key + (int(k),)
        cache_dtype = self._cache_dtype(self.woven.state)
        if cache_dtype:  # quantized pools emit different (clipped) logits
            key = key + (("cache_dtype", cache_dtype),)
        # armed fault injection and deadline policies make a serve
        # non-reproducible from its prompt key alone (the memo key carries
        # no pool geometry or fault schedule): bypass the memo entirely —
        # a hit would skip every join point, an update would poison the
        # table with fault-shaped outputs
        pre_inj = fault_injector if fault_injector is not None \
            else self.woven.state.extra.get("fault_injector")
        pre_deadline = deadline_s if deadline_s is not None \
            else self._resilience(self.woven.state)["deadline_s"]
        chunk_pre = prefill_chunk if prefill_chunk is not None \
            else self.cfg.prefill_chunk
        # a preemptible serve may drain mid-queue — same non-reproducibility.
        # Chunked/QoS/arrival serves keep token bit-parity but carry
        # per-wave stats and governor state a memo hit would silently skip,
        # so they bypass the table too (conservative: outputs would match).
        memo_ok = (pre_inj is None or not pre_inj.armed) \
            and pre_deadline is None and preemption is None \
            and not chunk_pre and arrival_waves is None \
            and not self._qos_enabled(qos)
        if memo_ok and self.memo is not None and self.memo.running:
            hit, out = self.memo.lookup(key)
            if hit:
                # a memo hit serves no decode steps and builds no pool:
                # clear the feedback window, the paged signature and the
                # pool stats so a following refine_kernel_tuner (or a
                # stats reader) never sees stale state from an earlier
                # (differently-shaped or differently-knobbed) serve
                self.decode_step_latencies = deque(
                    maxlen=self.history_window)
                self._step_lat_by_batch = {}
                self._paged_sig = None
                self._paged_dtype = None
                self.last_pool_stats = None
                self.last_spec_stats = None
                self.last_fault_stats = None
                self.last_outcomes = None
                self.last_qos_stats = None
                return out
        gen = self.serve_stream(
            prompts, decode_tokens=n, page_size=page_size,
            pool_pages=pool_pages, max_batch=max_batch,
            prefix_sharing=prefix_sharing, draft_len=draft_len,
            draft=draft, fault_injector=fault_injector,
            deadline_s=deadline_s, pool_audit=pool_audit,
            preemption=preemption, prefill_chunk=prefill_chunk, qos=qos,
            arrival_waves=arrival_waves, slo_ttft_s=slo_ttft_s,
            slo_tok_s=slo_tok_s)
        while True:
            try:
                ev = next(gen)
            except StopIteration as stop:
                result = stop.value
                break
            if on_event is not None:
                on_event(ev)
        # fault-shaped results (rejections, quarantines, deadline cuts)
        # must never be memoized: the memo key carries no pool geometry or
        # fault schedule, so a later right-sized serve would replay them
        fs = self.last_fault_stats
        clean = (memo_ok and fs["events"] == 0 and not fs["actions"]
                 and all(o["status"] == "ok" for o in self.last_outcomes))
        if self.memo is not None and clean:
            self.memo.update(key, result)
        return result

    def serve_stream(self, prompts: list[np.ndarray], *,
                     decode_tokens: int | None = None,
                     page_size: int | None = None,
                     pool_pages: int | None = None,
                     max_batch: int | None = None,
                     prefix_sharing: bool | None = None,
                     draft_len: int | None = None,
                     draft: "Server | None" = None,
                     fault_injector=None,
                     deadline_s: float | None = None,
                     pool_audit: bool | None = None,
                     preemption=None,
                     prefill_chunk: int | None = None,
                     qos=None,
                     arrival_waves=None,
                     slo_ttft_s: float | None = None,
                     slo_tok_s: float | None = None):
        """The streaming serving engine: a generator over per-token events.

        This is `serve_continuous`'s wave loop — admission, chunked
        prefill, decode/verify steps, retirement, fault isolation —
        refactored into an event loop that *yields* as tokens appear and
        *returns* the final per-request output list (read it from
        `StopIteration.value`, or use the `serve_continuous` wrapper).
        Event dicts (all carry "wave" — the logical wave index — and "t",
        a `perf_counter` stamp recorded at creation):

          {"event": "admit",         "rid": r}
          {"event": "prefill_chunk", "rid": r, "resident": i, "total": S}
          {"event": "token",  "rid": r, "token": t, "index": i}
          {"event": "outcome","rid": r, "status": s, "reason": ..., "tokens": n}
          {"event": "wave",   "batch": B, "dt_s": dt, "emitted": e,
           "prefill_tokens": p, "k": k_eff, "op": knobs-or-None}

        Chunked prefill (`prefill_chunk` > 0, ServerConfig, or the QoS
        governor's knob): a long admission reserves its block table up
        front, then prefills one page-aligned chunk per wave through the
        widened-q suffix-over-prefix shape, so in-flight decodes keep
        emitting a token every wave while the newcomer streams in — token
        outputs stay bit-identical to one-shot admission (see
        `_paged_admit_chunked` for the parity argument and gates).

        QoS control plane (`qos`: a QoSGovernor, a policy dict, a woven
        QoSAspect, or False to force off): the serving operating point —
        max_batch x prefill_chunk x draft_len x frequency (power cap) — is
        a mARGOt application re-selected online as load shifts, with
        per-request TTFT / per-token SLOs as Goal constraints and tokens/s
        or tokens/joule as the objective; observed wave latencies feed
        `Margot.observe` and the modeled power feeds the PowerCapper.
        Every emitted token is still a target argmax, so governor knob
        moves never change the output bytes — only when they appear.

        `arrival_waves` (one int per prompt) lands requests on a logical
        wave clock instead of all-at-wave-0 — the deterministic open-loop
        load ramp the qos bench drives.
        """
        if not prompts:
            return []
        n = decode_tokens or self.cfg.decode_tokens
        k = draft_len if draft_len is not None else self.cfg.draft_len
        t0 = time.perf_counter()
        variant = self._variant()
        state = self.woven.variant_state(
            None if variant in (None, "__default__") else variant
        )
        state.extra["cache_max_len"] = self.cfg.max_cache_len
        ps = page_size or self._page_size(state)
        cache_dtype = self._cache_dtype(state)  # variant knobs win
        res = self._resilience(state)
        if deadline_s is not None:
            res["deadline_s"] = float(deadline_s)
        if pool_audit is not None:
            res["pool_audit"] = bool(pool_audit)
        inj = fault_injector if fault_injector is not None \
            else state.extra.get("fault_injector")
        gov = self._qos_governor(state, qos, slo_ttft_s, slo_tok_s)
        # chunked prefill: explicit argument, then ServerConfig, then the
        # governor's prefill_chunk knob (0/None: one-shot admission).
        # Capacity-routed MoE couples prefill tokens within the group
        # (capacity/drop decisions see the whole dispatch), so a chunked
        # prefill would not be bit-identical — the gate stays off there.
        chunk_ok = self.woven.program.cfg.family != "moe"
        chunk_cfg = prefill_chunk if prefill_chunk is not None \
            else self.cfg.prefill_chunk

        if k is None:
            k = int(state.extra.get("speculative_draft_len", 0) or 0)
        k = max(0, int(k))
        # the governor may raise draft_len at runtime: reserve verify
        # slack (and size the draft pool) for the largest knob value
        k_max = k
        if gov is not None:
            k_max = max([k] + [int(v) for v in gov.knob_values("draft_len")])

        lengths = [int(np.asarray(p).reshape(-1).shape[0]) for p in prompts]
        # speculative verify steps write up to k slots past the accepted
        # length before rolling back — reserve that slack at admission so
        # draft-block writes can never outrun the block table
        finals = [min(S + n - 1 + k_max, self.cfg.max_cache_len)
                  for S in lengths]
        max_batch = max_batch or self.cfg.max_batch or len(prompts)
        pool_pages = pool_pages or self.cfg.pool_pages \
            or max(sum(cdiv(f, ps) for f in finals), 1)
        share = self.cfg.prefix_sharing if prefix_sharing is None \
            else prefix_sharing
        if self.woven.program.cfg.family == "moe":
            # Capacity-routed MoE couples tokens within a sequence group
            # (the capacity C and drop decisions depend on the whole
            # group), so prefix K/V are not request-independent — a
            # sharer's recompute could write *different* bytes into pages
            # the donor still maps.  Prefix sharing stays off; the
            # direct-to-pool paged prefill still applies.
            share = False
        manager = PagedCacheManager(
            pool_pages, ps, max_len=self.cfg.max_cache_len,
            window=getattr(self.woven.program.cfg, "attn_window", None),
            prefix_sharing=share, cache_dtype=cache_dtype,
        )
        # feedback observations are per-knob-setting: start a fresh window,
        # bucketed by batch size (a decode step's cost scales with the live
        # batch, and the DSE signature is keyed to one batch)
        self.decode_step_latencies = deque(maxlen=self.history_window)
        self._step_lat_by_batch = {}

        if k_max and self.woven.program.cfg.family == "moe":
            # Capacity-routed MoE couples tokens within a group: a verify
            # step's S-token router sees different capacity/drop decisions
            # than S sequential one-token steps, so verify logits would
            # not be bit-identical to plain decode.  Speculation stays off.
            k = k_max = 0
        draft_srv = draft or self.draft or self  # self-speculation default
        dmanager: PagedCacheManager | None = None
        if k_max:
            # the draft keeps its own (unshared) page pool with the same
            # continuous-batching dynamics; sized for full concurrency so
            # a draft admission can never fail behind a target admission
            dstate = draft_srv.woven.variant_state(None)
            dstate.extra["cache_max_len"] = self.cfg.max_cache_len
            dmanager = PagedCacheManager(
                max(sum(cdiv(f, ps) for f in finals), 1), ps,
                max_len=self.cfg.max_cache_len,
                window=getattr(draft_srv.woven.program.cfg,
                               "attn_window", None),
                prefix_sharing=False, cache_dtype=cache_dtype,
            )

        # logical-clock arrivals: requests with a future arrival wave sit
        # in `pending` until the wave counter reaches them — the
        # deterministic open-loop ramp the qos bench drives.  Default
        # (None): everything arrives at wave 0, exactly the old semantics.
        arrive_at = None
        if arrival_waves is not None:
            if len(arrival_waves) != len(prompts):
                raise ValueError("arrival_waves must have one wave index "
                                 "per prompt")
            arrive_at = [max(0, int(w)) for w in arrival_waves]
        waiting: deque = deque()              # arrived, not yet admitted
        pending: deque = deque()              # not yet arrived (wave clock)
        if arrive_at is None:
            waiting.extend(range(len(prompts)))
        else:
            pending.extend(sorted(range(len(prompts)),
                                  key=lambda r: (arrive_at[r], r)))
        active: dict[int, dict] = {}          # rid -> {"tok", "pos"}
        prefilling: dict[int, Any] = {}       # rid -> chunked-admit cont
        outputs: dict[int, list[int]] = {}
        seen_batches: set[int] = set()        # batch sizes already compiled
        spec = {"on": False, "checked": False}
        verify_lats: list[float] = []
        stats = {"draft_len": k, "rounds": 0, "request_rounds": 0,
                 "proposed": 0, "accepted": 0, "emitted_spec": 0,
                 "draft_steps": 0, "verify_steps": 0, "decode_steps": 0}

        grouped = {"admissions": 0}  # identical-prompt shared re-scores

        # the live operating point: base values from the arguments/config,
        # re-selected by the governor as load shifts (closures read this)
        knobs = {"max_batch": max_batch,
                 "chunk": int(chunk_cfg or 0) if chunk_ok else 0,
                 "k": k, "freq": 1.0}

        # per-token stream events accumulate here and are yielded at wave
        # boundaries; "t" is stamped at creation so latency math is exact
        # regardless of when the consumer drains
        evq: list[dict] = []
        wave = 0
        wavestat = {"emitted": 0, "prefill_tokens": 0}
        now0 = time.perf_counter()
        rq: dict[int, dict] = {
            r: {"arrive_t": now0, "arrive_wave": 0, "first_t": None,
                "first_wave": None, "tok_t": []}
            for r in range(len(prompts))}

        def _emit(kind: str, **kw) -> None:
            evq.append({"event": kind, "wave": wave,
                        "t": time.perf_counter(), **kw})

        def _first_token(rid, tok) -> None:
            outputs[rid] = [tok]
            active[rid] = {"tok": tok, "pos": lengths[rid]}
            m = rq[rid]
            m["first_t"] = time.perf_counter()
            m["first_wave"] = wave
            m["tok_t"].append(m["first_t"])
            wavestat["emitted"] += 1
            _emit("token", rid=rid, token=tok, index=0)
            if gov is not None:
                gov.observe("ttft_s", m["first_t"] - m["arrive_t"])

        # -- resilience machinery ---------------------------------------------
        # every fault the policy can absorb lands in `outcome` / `actions`
        # instead of escaping serve_continuous; with no injector woven and
        # no deadline policy this layer is pass-through and serving is
        # bit-identical to the fault-free path
        outcome = {r: {"status": "ok", "reason": None}
                   for r in range(len(prompts))}
        actions: list[dict] = []  # recovery actions taken (host side)
        inj_seen = len(inj.events) if inj is not None else 0
        fstats = {"retries": 0, "quarantined": 0, "rejected": 0,
                  "oversized": 0, "deadline_exceeded": 0, "failed": 0,
                  "drained": 0, "degraded": None, "audits": 0,
                  "watchdog_timeouts": 0}
        start_t: dict[int, float] = {}     # admission wall clock per request
        forced_deadline: set[int] = set()  # injected SLO overruns
        deadline_s_eff = res["deadline_s"]
        retries_max = int(res["retries"])
        backoff_s = float(res["backoff_s"])
        watchdog: Watchdog | None = None
        if res["step_deadline_s"]:
            watchdog = Watchdog(
                float(res["step_deadline_s"]),
                lambda: actions.append({"point": "decode_step",
                                        "kind": "watchdog_overrun"}))

        class _StepAbort(Exception):
            """A step failed past the retry budget (or non-transiently):
            the serve drains with structured `failed` outcomes instead of
            letting the exception escape."""

            def __init__(self, point, cause):
                super().__init__(f"{point}: {cause}")
                self.point, self.cause = point, cause

        def _fire(point, *, rid=None, rids=None):
            if inj is None:
                return None
            fired = inj.fire(point, rid=rid, rids=rids)
            if fired is not None and fired.kind == "deadline" \
                    and fired.rid is not None:
                # SLO overrun: the victim is forced past its deadline; the
                # sweep at the next round start retires it with partial
                # output
                forced_deadline.add(fired.rid)
            return fired

        def _retry(point, fn):
            """Bounded retry-with-backoff around one step's transient
            faults (injected raises / pool exhaustion fire *before* the
            jitted dispatch, so re-running is safe even though the step
            donates its cache; manager.batch is idempotent).  Anything
            else aborts the serve's stepping via _StepAbort — never by
            letting the exception escape."""
            attempt = 0
            while True:
                try:
                    return fn()
                except (FaultError, PoolExhausted) as e:
                    attempt += 1
                    fstats["retries"] += 1
                    actions.append({"point": point, "kind": "retry",
                                    "attempt": attempt, "error": str(e)})
                    if attempt > retries_max:
                        raise _StepAbort(point, e) from e
                    if backoff_s:
                        time.sleep(backoff_s * (2 ** (attempt - 1)))
                except Exception as e:  # non-transient: no retry
                    raise _StepAbort(point, e) from e

        def _audit():
            # PoolAuditor barriers under the debug knob: corruption is
            # caught at the fault, not three steps later
            if not res["pool_audit"]:
                return
            fstats["audits"] += 1
            PoolAuditor(manager, check_device=True).audit()
            if dmanager is not None:
                PoolAuditor(dmanager).audit()

        def _reject(rid, reason, status="rejected"):
            outcome[rid] = {"status": status, "reason": reason}
            fstats[status] += 1
            actions.append({"point": "admit", "kind": status, "rid": rid,
                            "reason": reason})
            _emit("outcome", rid=rid, status=status, reason=reason,
                  tokens=len(outputs.get(rid, [])))

        def _drop(rid):
            """Release every trace of `rid` from both pools + the batch."""
            manager.abort(rid)
            if dmanager is not None:
                dmanager.abort(rid)
            active.pop(rid, None)
            prefilling.pop(rid, None)
            start_t.pop(rid, None)
            forced_deadline.discard(rid)

        def _quarantine(rid, reason):
            # NaN/Inf logits quarantine exactly the victim: its pages
            # retire, its partial output survives, the batch re-forms
            outcome[rid] = {"status": "quarantined", "reason": reason}
            fstats["quarantined"] += 1
            actions.append({"point": "decode_step", "kind": "quarantined",
                            "rid": rid, "reason": reason})
            _emit("outcome", rid=rid, status="quarantined", reason=reason,
                  tokens=len(outputs.get(rid, [])))
            _drop(rid)

        def _degrade(reason):
            """Speculation is an optimization: any draft-side fault (or
            repeated all-reject verify rounds under the patience policy)
            turns it off for the rest of the serve — a draft failure never
            touches target state, so output parity holds."""
            if not spec["on"]:
                return
            spec["on"] = False
            fstats["degraded"] = reason
            actions.append({"point": "draft_step", "kind": "degraded",
                            "reason": reason})
            if dmanager is not None:
                for r in list(dmanager.pool.tables):
                    dmanager.abort(r)

        def _retire(rid):
            try:
                _retry("retire", lambda: (_fire("retire", rid=rid),
                                          manager.retire(rid)))
            except _StepAbort as e:
                # a retire that keeps failing force-drops the references —
                # leaking pages on a fault path would starve later
                # admissions
                manager.abort(rid)
                actions.append({"point": "retire", "kind": "forced_abort",
                                "rid": rid, "error": str(e.cause)})
            if dmanager is not None:
                dmanager.abort(rid)

        def admit_one(rid, reuse_from=None) -> None:
            aspec = _fire("admit", rid=rid)
            if aspec is not None and aspec.kind == "nan_logits":
                # an admission with poisoned logits has no usable first
                # token: reject it through the non-finite path
                raise NonFiniteLogits(
                    f"injected non-finite admission logits for {rid!r}")
            _emit("admit", rid=rid)
            tok = None
            if reuse_from is not None:
                tok = self._admit_grouped(manager, rid, prompts[rid],
                                          finals[rid],
                                          outputs[reuse_from][0])
                if tok is not None:
                    grouped["admissions"] += 1
            cont = None
            if tok is None:
                chunk = int(knobs["chunk"] or 0)
                if chunk > 0:
                    tok, pspec, cont = self._paged_admit_chunked(
                        manager, rid, prompts[rid], finals[rid], variant,
                        inj=inj, chunk=chunk)
                else:
                    tok, pspec = self._paged_admit(
                        manager, rid, prompts[rid], finals[rid], variant,
                        inj=inj)
                if pspec is not None and pspec.kind == "deadline":
                    forced_deadline.add(rid)
                if cont is None:
                    # a one-shot admission processed the whole prompt this
                    # wave — billed into the wave event like a chunk is, so
                    # the governor's tok_s observations (and any modeled
                    # clock over the event stream) see admission stalls on
                    # both prefill paths
                    wavestat["prefill_tokens"] += lengths[rid]
            start_t[rid] = time.monotonic()
            if cont is not None:
                # chunked admission in flight: the block table is
                # reserved, the prompt streams in one chunk per wave
                prefilling[rid] = cont
            else:
                _first_token(rid, tok)
            if not spec["checked"]:
                # pool family is known after the first admission: ring
                # pools evict on write, which breaks the widened-q verify
                # mask — the server gates speculation to linear pools
                spec["checked"] = True
                spec["on"] = bool(k_max) and not manager._ring_pool()
            if spec["on"]:
                # draft admits in lockstep (its length must equal the
                # target's accepted length at every round start); a draft
                # admission fault degrades speculation but keeps the
                # target admission — the request decodes plain.  This also
                # closes the old leak where a draft throw stranded the
                # target's pages and `active`/`outputs` entries.
                try:
                    draft_srv._paged_admit(dmanager, rid, prompts[rid],
                                           finals[rid], None, inj=inj)
                except Exception as e:
                    _degrade(f"draft admission failed: {e}")

        def try_admit(rid, reuse_from=None) -> bool:
            try:
                admit_one(rid, reuse_from)
                return True
            except (FaultError, PoolExhausted) as e:
                # a failed admission is isolated to the one request: its
                # partial pool state rolls back and it gets a structured
                # rejection — the serve (and every other request) goes on
                outputs.pop(rid, None)
                _drop(rid)
                _reject(rid, str(e))
                _audit()
                return False

        def admit_ready() -> None:
            # the admission gate counts chunked prefills in flight: they
            # hold reserved pages and will join the decode batch, so the
            # governor's max_batch knob bounds active + prefilling
            while waiting and (len(active) + len(prefilling)
                               < int(knobs["max_batch"])):
                rid = None
                if manager.prefix_sharing and len(waiting) > 1:
                    # prefix-aware admission: a sharer queued behind a
                    # non-sharer jumps the line while its donor's pages
                    # are still live — the shared prefix costs it no fresh
                    # pages, so it can fit where the queue head cannot
                    # (and the hit is lost once the donor retires)
                    for cand in waiting:
                        toks_np = np.asarray(prompts[cand],
                                             np.int64).reshape(-1)
                        _, sl = manager.match_prefix(toks_np)
                        if sl > 0 and manager.can_admit(
                                finals[cand], tokens=prompts[cand]):
                            rid = cand
                            break
                if rid is None:
                    rid = waiting[0]
                    # capacity-checked for the very first admission too: an
                    # oversized request is rejected *before* its prefill
                    # runs, landing on the clean "page pool too small" path
                    # below instead of a raw PoolExhausted out of pool.alloc
                    if not manager.can_admit(finals[rid], tokens=prompts[rid]):
                        return
                ok = try_admit(rid)
                waiting.remove(rid)
                if not (ok and manager.prefix_sharing and waiting
                        and self._last_admit_rescored):
                    continue
                # identical queued prompts admit as a group sharing the
                # re-score that just ran: each member maps the same pages
                # and reuses the donor's first token — one re-score step
                # for the whole group instead of one per member
                base = np.asarray(prompts[rid], np.int64).reshape(-1)
                for cand in [c for c in waiting if np.array_equal(
                        np.asarray(prompts[c], np.int64).reshape(-1), base)]:
                    if (len(active) + len(prefilling)
                            >= int(knobs["max_batch"])) \
                            or not manager.can_admit(
                                finals[cand], tokens=prompts[cand]):
                        break
                    try_admit(cand, reuse_from=rid)
                    waiting.remove(cand)

        def _drain_waiting() -> None:
            """Preemption: hand the not-yet-admitted queue back with
            structured `drained` outcomes — in-flight work is untouched.
            Future arrivals (the logical-clock `pending` queue) drain too:
            a preempted replica will never reach their wave."""
            while pending:
                waiting.append(pending.popleft())
            while waiting:
                rid = waiting.popleft()
                outcome[rid] = {"status": "drained",
                                "reason": "preemption requested: "
                                          "admissions stopped"}
                fstats["drained"] += 1
                actions.append({"point": "drain", "kind": "drained",
                                "rid": rid})
                _emit("outcome", rid=rid, status="drained",
                      reason=outcome[rid]["reason"], tokens=0)

        def _admit_or_drain() -> None:
            if preemption is not None and preemption.pending:
                _drain_waiting()
                return
            admit_ready()

        # prompts the cache could never host are rejected up front — the
        # old path crashed the whole serve mid-flight on the first one
        for r in [r for r in list(waiting) + list(pending)
                  if lengths[r] > self.cfg.max_cache_len]:
            (waiting if r in waiting else pending).remove(r)
            _reject(r, f"prompt ({lengths[r]} tokens) exceeds "
                       f"max_cache_len ({self.cfg.max_cache_len})",
                    status="oversized")

        mismatch_rounds = 0
        aborted: Exception | None = None
        while active or waiting or prefilling or pending:
            while evq:
                yield evq.pop(0)
            t_wave = time.perf_counter()
            wavestat["emitted"] = 0
            wavestat["prefill_tokens"] = 0
            # logical-clock arrivals land before anything else this wave
            if pending:
                arrived = False
                while pending and arrive_at[pending[0]] <= wave:
                    r = pending.popleft()
                    waiting.append(r)
                    rq[r]["arrive_t"] = time.perf_counter()
                    rq[r]["arrive_wave"] = wave
                    arrived = True
                if arrived:
                    _admit_or_drain()
            # the governor re-selects the serving operating point as load
            # shifts (every reselect_every waves); knob moves only change
            # scheduling — every emitted token stays a target argmax
            if gov is not None and wave % gov.reselect_every == 0:
                op = gov.decide(wave=wave,
                                waiting=len(waiting) + len(pending),
                                active=len(active) + len(prefilling))
                v = op.get("max_batch")
                knobs["max_batch"] = min(max_batch, int(v)) if v \
                    else max_batch
                if chunk_ok and chunk_cfg is None \
                        and op.get("prefill_chunk") is not None:
                    knobs["chunk"] = int(op["prefill_chunk"])
                if op.get("draft_len") is not None:
                    knobs["k"] = min(k_max, int(op["draft_len"]))
                if op.get("freq") is not None:
                    knobs["freq"] = float(op["freq"])
            if wave == 0:
                _admit_or_drain()
            # preemption arriving mid-wave drains the queue at the next
            # round boundary; the admitted batch keeps decoding to the end
            if preemption is not None and preemption.pending \
                    and (waiting or pending):
                _drain_waiting()
                if not active and not prefilling:
                    break
            # retire before stepping: requests at their budget free pages
            done = [r for r in active if len(outputs[r]) >= n]
            for rid in done:
                _retire(rid)
                del active[rid]
                _emit("outcome", rid=rid, status=outcome[rid]["status"],
                      reason=outcome[rid]["reason"],
                      tokens=len(outputs[rid][:n]))
            # per-request SLO sweep: overdue requests (wall clock past
            # deadline_s, or forced over by an injected `deadline` fault)
            # retire with partial output and a deadline_exceeded marker —
            # chunked admissions still prefilling are swept too (their
            # clock started at reservation)
            overdue = []
            if deadline_s_eff is not None or forced_deadline:
                now = time.monotonic()
                overdue = [r for r in list(active) + list(prefilling)
                           if r in forced_deadline
                           or (deadline_s_eff is not None
                               and now - start_t[r] > deadline_s_eff)]
            for rid in overdue:
                outcome[rid] = {"status": "deadline_exceeded",
                                "reason": "request exceeded its deadline"}
                fstats["deadline_exceeded"] += 1
                actions.append({"point": "decode_step", "kind": "deadline",
                                "rid": rid,
                                "emitted": len(outputs.get(rid, []))})
                _emit("outcome", rid=rid, status="deadline_exceeded",
                      reason=outcome[rid]["reason"],
                      tokens=len(outputs.get(rid, [])))
                if rid in prefilling:
                    # mid-prefill: nothing registered yet — abort the
                    # reserved pages instead of retiring
                    del prefilling[rid]
                    _drop(rid)
                else:
                    _retire(rid)
                    active.pop(rid, None)
                forced_deadline.discard(rid)
            if done or overdue:
                _audit()
                _admit_or_drain()
            # advance chunked prefills: one page-aligned chunk per request
            # per wave — in-flight decodes below never wait on a long
            # admission, the newcomer streams in beside them
            for rid in list(prefilling):
                try:
                    step_r = prefilling[rid]()
                except (FaultError, PoolExhausted) as e:
                    outputs.pop(rid, None)
                    _drop(rid)
                    _reject(rid, str(e))
                    _audit()
                    continue
                wavestat["prefill_tokens"] += step_r["chunk"]
                if step_r["tok"] is None:
                    _emit("prefill_chunk", rid=rid,
                          resident=step_r["resident"], total=lengths[rid])
                else:
                    del prefilling[rid]
                    _first_token(rid, step_r["tok"])
            if not active:
                if waiting and not prefilling:
                    # pool at its emptiest still can't fit the head
                    # request: reject *it* and keep serving the rest — the
                    # old batch-killing RuntimeError here threw away every
                    # completed request's output with it
                    rid = waiting.popleft()
                    _reject(rid, f"page pool too small: request {rid} "
                                 f"needs more pages than the pool holds")
                    _admit_or_drain()
                    wave += 1
                    continue
                if prefilling or pending:
                    # nothing to decode this wave: prefill chunks advanced
                    # above / the clock ticks toward the next arrival
                    wave += 1
                    continue
                break

            rids = list(active)
            # a verify round writes k_eff+1 slots per request; past the
            # final_len clamp (cache capacity) fall back to plain rounds —
            # S stays within the knob grid so only a few step shapes ever
            # compile (static serves keep the old {1, k+1} pair)
            k_eff = int(knobs["k"]) if spec["on"] else 0
            S = k_eff + 1 if (k_eff and spec["on"] and all(
                active[r]["pos"] + k_eff + 1 <= finals[r] for r in rids)) \
                else 1

            if S > 1 and dmanager is not None:
                # dynamic draft_len: plain waves (k_eff == 0 under the
                # governor) leave the draft cache behind the target's
                # accepted length — replay the emitted tokens through the
                # draft before the round so the lockstep invariant holds.
                # Static-k serves never enter the replay loop.
                try:
                    self._draft_sync(draft_srv, dmanager, rids, active,
                                     outputs, lengths)
                except Exception as e:
                    _degrade(f"draft catch-up fault: {e}")
                    S = 1

            if S > 1:
                pos0 = {r: active[r]["pos"] for r in rids}
                fed = np.zeros((len(rids), S), np.int64)
                fed[:, 0] = [active[r]["tok"] for r in rids]
                # draft proposes k greedy tokens; the final iteration is a
                # write-only catch-up (its KV for slot pos+k is needed when
                # every draft token is accepted), its proposal is unused
                try:
                    for s in range(S):
                        dspec = _fire("draft_step", rids=rids)
                        dcache = dmanager.batch(rids)
                        dpos = jnp.asarray([[pos0[r] + s] for r in rids],
                                           jnp.int32)
                        dlogits, dnew = draft_srv.decode_vc(
                            None, draft_srv.params,
                            {"tokens": jnp.asarray(fed[:, s:s + 1],
                                                   jnp.int32),
                             "positions": dpos},
                            dcache)
                        if dspec is not None \
                                and dspec.kind == "nan_logits":
                            # a poisoned proposal is still a legal token
                            # after argmax (NaN rows argmax to 0): the
                            # verify step rejects garbage proposals, so a
                            # bad draft costs steps, never correctness
                            vi = rids.index(dspec.rid) \
                                if dspec.rid in rids else 0
                            dlogits = dlogits.at[vi].set(jnp.nan)
                        dmanager.absorb(rids, dnew)
                        stats["draft_steps"] += 1
                        if s < S - 1:
                            fed[:, s + 1] = np.asarray(
                                jnp.argmax(dlogits[:, -1], axis=-1),
                                np.int64)
                except Exception as e:
                    # draft-side fault: no target state was touched this
                    # round — degrade to plain decode and re-run the round
                    _degrade(f"draft fault: {e}")
                    wave += 1
                    continue

                # ONE widened-q target step scores all S draft positions
                def _verify_round():
                    _fire("cow", rids=rids)
                    cache = manager.batch(rids, tokens=S)
                    vspec = _fire("verify_step", rids=rids)
                    vpos = jnp.asarray(
                        [[pos0[r] + s for s in range(S)] for r in rids],
                        jnp.int32)
                    ts = time.perf_counter()
                    if watchdog is not None:
                        watchdog.beat()
                    logits, new_cache = self._verify_step(variant, k_eff)(
                        self.params,
                        {"tokens": jnp.asarray(fed, jnp.int32),
                         "positions": vpos},
                        cache)
                    if watchdog is not None:
                        watchdog.cancel()
                    return vspec, ts, logits, new_cache

                try:
                    vspec, ts, logits, new_cache = _retry("verify_step",
                                                          _verify_round)
                except _StepAbort as err:
                    aborted = err
                    break
                if vspec is not None and vspec.kind == "nan_logits":
                    vi = rids.index(vspec.rid) if vspec.rid in rids else 0
                    logits = logits.at[vi].set(jnp.nan)
                finite = np.asarray(jnp.isfinite(jnp.max(
                    logits.astype(jnp.float32), axis=(-2, -1))))
                targ = np.asarray(jnp.argmax(logits, axis=-1), np.int64)
                if stats["verify_steps"]:  # skip the jit-tracing first step
                    verify_lats.append(time.perf_counter() - ts)
                manager.absorb(rids, new_cache, advance=S)
                stats["verify_steps"] += 1
                stats["rounds"] += 1
                stats["request_rounds"] += len(rids)
                accepted_round = 0
                rolled = False
                for i, rid in enumerate(rids):
                    if not finite[i]:
                        _quarantine(rid, "non-finite verify logits")
                        rolled = True
                        continue
                    # accept the longest draft prefix matching the
                    # target's own argmax chain, plus the correction
                    # token — every emitted token is a target argmax,
                    # so greedy output is bit-identical to plain decode
                    a = 0
                    while a < k_eff and fed[i, a + 1] == targ[i, a]:
                        a += 1
                    e = min(a + 1, n - len(outputs[rid]))
                    idx0 = len(outputs[rid])
                    outputs[rid].extend(int(t) for t in targ[i, :e])
                    new_len = pos0[rid] + e
                    # rejected tail: O(1) refcount rollback, no page copies
                    try:
                        _retry("rollback", lambda rid=rid, nl=new_len: (
                            _fire("rollback", rid=rid),
                            manager.rollback(rid, nl),
                            dmanager.rollback(rid, nl)))
                    except _StepAbort as err:
                        # a rollback that keeps failing leaves the
                        # request's length unknown: quarantine it
                        _quarantine(rid, f"rollback failed: {err.cause}")
                        rolled = True
                        continue
                    active[rid]["tok"] = int(targ[i, e - 1])
                    active[rid]["pos"] = new_len
                    t_tok = time.perf_counter()
                    for j in range(e):
                        rq[rid]["tok_t"].append(t_tok)
                        _emit("token", rid=rid, token=int(targ[i, j]),
                              index=idx0 + j)
                    wavestat["emitted"] += e
                    stats["proposed"] += k_eff
                    stats["accepted"] += a
                    stats["emitted_spec"] += e
                    accepted_round += a
                    rolled = True
                if rolled:
                    _audit()
                if accepted_round == 0:
                    mismatch_rounds += 1
                    patience = res["spec_patience"]
                    if patience is not None \
                            and mismatch_rounds >= int(patience):
                        _degrade(f"{mismatch_rounds} consecutive "
                                 f"all-reject verify rounds")
                else:
                    mismatch_rounds = 0
            else:
                def _decode_round():
                    _fire("cow", rids=rids)
                    cache = manager.batch(rids)
                    pspec = _fire("decode_step", rids=rids)
                    tok = jnp.asarray([[active[r]["tok"]] for r in rids],
                                      jnp.int32)
                    pos = jnp.asarray([[active[r]["pos"]] for r in rids],
                                      jnp.int32)
                    ts = time.perf_counter()
                    if watchdog is not None:
                        watchdog.beat()
                    logits, new_cache = self.decode_vc(
                        variant, self.params,
                        {"tokens": tok, "positions": pos}, cache,
                    )
                    if watchdog is not None:
                        watchdog.cancel()
                    return pspec, ts, logits, new_cache

                try:
                    pspec, ts, logits, new_cache = _retry("decode_step",
                                                          _decode_round)
                except _StepAbort as err:
                    aborted = err
                    break
                if pspec is not None and pspec.kind == "nan_logits":
                    vi = rids.index(pspec.rid) if pspec.rid in rids else 0
                    logits = logits.at[vi].set(jnp.nan)
                finite = np.asarray(jnp.isfinite(jnp.max(
                    logits[:, -1].astype(jnp.float32), axis=-1)))
                nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int64)
                # first step at each batch size pays jit tracing —
                # excluding it keeps the tuner-feedback observations
                # compile-free (the DSE expectations were measured
                # post-compile too)
                if len(rids) in seen_batches:
                    dt_step = time.perf_counter() - ts
                    self.decode_step_latencies.append(dt_step)
                    self._step_lat_by_batch.setdefault(
                        len(rids),
                        deque(maxlen=self.history_window)).append(dt_step)
                seen_batches.add(len(rids))
                manager.absorb(rids, new_cache)
                stats["decode_steps"] += 1
                hit_nan = False
                t_tok = time.perf_counter()
                for i, rid in enumerate(rids):
                    if not finite[i]:
                        _quarantine(rid, "non-finite decode logits")
                        hit_nan = True
                        continue
                    idx0 = len(outputs[rid])
                    outputs[rid].append(int(nxt[i]))
                    active[rid]["tok"] = int(nxt[i])
                    active[rid]["pos"] += 1
                    rq[rid]["tok_t"].append(t_tok)
                    wavestat["emitted"] += 1
                    _emit("token", rid=rid, token=int(nxt[i]), index=idx0)
                if hit_nan:
                    _audit()

            # wave boundary: one "wave" event carries the batch shape, the
            # operating point in force, and this wave's emission/prefill
            # work; the governor observes the same numbers through its
            # MAPE-K loop (modeled wave latency → Margot.observe)
            dt_wave = time.perf_counter() - t_wave
            _emit("wave", batch=len(rids), dt_s=dt_wave,
                  emitted=wavestat["emitted"],
                  prefill_tokens=wavestat["prefill_tokens"],
                  k=(k_eff if S > 1 else 0),
                  op=(dict(knobs) if gov is not None else None))
            if gov is not None:
                gov.observe_wave(dt_wave, batch=len(rids),
                                 emitted=wavestat["emitted"],
                                 prefill_tokens=wavestat["prefill_tokens"],
                                 wave=wave)
            wave += 1

        if aborted is not None:
            # a step failed past its retry budget: every in-flight request
            # fails *structurally* (partial output kept, pool released) —
            # the exception itself never escapes
            for rid in list(active) + list(prefilling):
                outcome[rid] = {"status": "failed",
                                "reason": f"{aborted.point} failed: "
                                          f"{aborted.cause}"}
                fstats["failed"] += 1
                if rid in prefilling:
                    outputs.pop(rid, None)
                _emit("outcome", rid=rid, status="failed",
                      reason=outcome[rid]["reason"],
                      tokens=len(outputs.get(rid, [])))
                _drop(rid)
            while pending:
                waiting.append(pending.popleft())
            while waiting:
                _reject(waiting.popleft(),
                        f"serve aborted at {aborted.point}",
                        status="failed")
        if watchdog is not None:
            fstats["watchdog_timeouts"] = watchdog.timeouts
            watchdog.close()
        _audit()  # final barrier: the drained pools must be consistent

        self.last_pool_stats = manager.stats()
        self.last_pool_stats["grouped_admissions"] = grouped["admissions"]
        if k_max:
            p = stats["proposed"]
            stats["acceptance"] = stats["accepted"] / p if p else 0.0
            stats["mean_tokens_per_verify"] = (
                stats["emitted_spec"] / stats["request_rounds"]
                if stats["request_rounds"] else 0.0)
            stats["target_steps"] = (stats["verify_steps"]
                                     + stats["decode_steps"])
            stats["verify_latency_s"] = (
                float(np.mean(verify_lats)) if verify_lats else None)
            self.last_spec_stats = stats
        else:
            self.last_spec_stats = None
        if manager._groups:
            self._paged_dtype = next(iter(manager._groups.values()))["dtype"]
            self._paged_sig = self._paged_signature(
                batch=min(max_batch, len(prompts)), dtype=self._paged_dtype)
        else:
            # every request was rejected before the pool learned its
            # structure — no kernel signature to refine against
            self._paged_dtype = None
            self._paged_sig = None
        injected = list(inj.events[inj_seen:]) if inj is not None else []
        for ev in injected:
            self.broker.publish(
                f"serve/fault/{ev['point']}/{ev['kind']}"
                f"@host{jax.process_index()}", 1.0)
        by_status: dict[str, int] = {}
        for r in range(len(prompts)):
            s = outcome[r]["status"]
            by_status[s] = by_status.get(s, 0) + 1
        self.last_fault_stats = {"events": len(injected),
                                 "injected_events": injected,
                                 "actions": actions,
                                 "outcomes": by_status, **fstats}
        self.last_qos_stats = gov.stats() if gov is not None else None

        def _outcome_row(r):
            m = rq[r]
            row = {"rid": r, "status": outcome[r]["status"],
                   "reason": outcome[r]["reason"],
                   "tokens": len(outputs.get(r, [])[:n]),
                   "ttft_s": None, "ttft_waves": None,
                   "tok_gap_max_s": None}
            if m["first_t"] is not None:
                row["ttft_s"] = m["first_t"] - m["arrive_t"]
                row["ttft_waves"] = m["first_wave"] - m["arrive_wave"]
            tt = m["tok_t"]
            if len(tt) > 1:
                row["tok_gap_max_s"] = max(
                    b - a for a, b in zip(tt, tt[1:]))
            return row

        self.last_outcomes = [_outcome_row(r) for r in range(len(prompts))]
        result = [np.asarray(outputs.get(r, [])[:n], np.int64)
                  for r in range(len(prompts))]
        dt = time.perf_counter() - t0
        self.latencies.append(dt)
        self.served += len(prompts)
        self.broker.publish(f"serve/latency/@host{jax.process_index()}", dt)
        if self.margot is not None:
            self.margot.observe("latency", dt)
        while evq:
            yield evq.pop(0)
        return result

    def _paged_signature(self, *, batch: int, dtype):
        """The signature `ops.flash_decode`'s tuned_paged_blocks lookup
        keys on for this server's decode steps — the served KV dtype and
        the logical cache length the kernel actually sees (the window for
        ring layouts)."""
        from repro.autotune.kernel_tuner import paged_decode_signature

        cfg = self.woven.program.cfg
        cache_len = self.cfg.max_cache_len
        window = getattr(cfg, "attn_window", None)
        if window is not None and window < cache_len:
            cache_len, window = window, None  # ring layout
        return paged_decode_signature(
            batch, cache_len, cfg.n_heads, cfg.kv_heads,
            cfg.resolved_head_dim, dtype, window=window,
        )

    def refine_kernel_tuner(self, *, latency_budget: float,
                            tuner=None) -> dict | None:
        """Feed observed decode-step latencies back into the persistent
        kernel-tuner cache (repro.autotune.kernel_tuner.refine_from_runtime):
        serving traffic refines the DSE priors, so the next server process
        picks page/block knobs selected under *observed* — not predicted —
        latency.  Returns the re-selected knobs (None if never tuned)."""
        from repro.autotune.kernel_tuner import refine_from_runtime

        if self._paged_sig is None or not self._step_lat_by_batch:
            return None
        # continuous batching shrinks the batch as requests retire; a step's
        # cost scales with the live batch, so observe only the best-sampled
        # batch size and refine the signature keyed to *that* batch
        batch = max(self._step_lat_by_batch,
                    key=lambda b: len(self._step_lat_by_batch[b]))
        observed = float(np.mean(self._step_lat_by_batch[batch]))
        sig = self._paged_signature(batch=batch, dtype=self._paged_dtype)
        return refine_from_runtime(
            sig, {"latency_s": observed},
            tuner=tuner, latency_budget=latency_budget,
            objective_knob="page_size",
        )

    def refine_speculative(self, *, latency_budget: float,
                           tuner=None) -> dict | None:
        """Feed the observed draft acceptance back into the persistent
        speculative-space entry: the served `mean_tokens_per_verify`
        (acceptance x draft_len + 1) rescales the cached acceptance-1
        `tokens_per_step` priors and the verify-step latency rescales the
        latency expectations, then the `draft_len` knob is re-selected
        under the adjusted budget.  Returns the re-selected knobs (None
        when the last serve was not speculative or never tuned)."""
        from repro.autotune.kernel_tuner import (
            refine_from_runtime,
            speculative_signature,
        )

        stats = self.last_spec_stats
        if not stats or not stats.get("verify_steps"):
            return None
        cfg = self.woven.program.cfg
        cache_len = self.cfg.max_cache_len
        window = getattr(cfg, "attn_window", None)
        if window is not None and window < cache_len:
            cache_len, window = window, None  # ring layout
        batch = max(1, round(stats["request_rounds"] / max(stats["rounds"], 1)))
        sig = speculative_signature(
            batch, cache_len, cfg.n_heads, cfg.kv_heads,
            cfg.resolved_head_dim, self._paged_dtype or "bfloat16",
            window=window,
        )
        observed = {"tokens_per_step": float(stats["mean_tokens_per_verify"])}
        if stats.get("verify_latency_s"):
            observed["latency_s"] = float(stats["verify_latency_s"])
        return refine_from_runtime(
            sig, observed, tuner=tuner, latency_budget=latency_budget,
            objective_knob="draft_len",
        )
