"""Paged KV-cache pool: the vLLM block-table layout for the serving runtime.

`stack_request_caches` (PR 3) batches variable-length requests by padding
every per-request cache to the same length — HBM scales with
batch x max_len even when most requests are short.  This module replaces
that with one shared pool of fixed-size pages per layer:

  PagePool            host-side refcounted free-list allocator: physical
                      pages are allocated on admission, appended at the
                      logical tail as a request's cache grows past a page
                      boundary (decode writes are strictly sequential in
                      slot space, so growth is always contiguous-tail),
                      and released when the request retires.  Pages can be
                      *shared* across requests (prefix caching): alloc
                      takes a shared-page prefix whose refcounts bump
                      instead of consuming free pages, release decrements,
                      and `cow` splits a shared page (copy -> remap) the
                      moment its holder needs to write it.  Admission is
                      reservation-aware: a request is only admitted when
                      the pool can cover every active request's *worst
                      case* growth (including potential copy-on-write
                      splits of its shared pages), so decode can never
                      deadlock on pages.

  PagedCacheManager   device-side owner of the per-layer page pools.  It
                      admits requests by writing their prefill K/V
                      *directly into pool pages* (the paged-prefill path
                      through Attention — no transient dense max_len
                      cache), maps a new request's common prompt prefix
                      onto existing physical pages through a token-hash
                      prefix index, re-forms the batched decode cache
                      pytree for whatever set of requests is active *this
                      step* (continuous batching: the batch is recomposed
                      every token), splits shared pages copy-on-write
                      before the decode step that would write them, and
                      absorbs the post-step pools / ring `pos` rows /
                      `kv_pos` rows back into per-request state.

The resulting cache pytree is what `Attention._decode`'s paged branch and
the block-table `flash_decode` kernel consume: per layer `{"pk", "pv"}`
pools of shape (P, page_size, K, D) (leading layer dim under a scanned
stack) with per-request `index`, ring `pos`, and one shared top-level
`block_tables` (B, num_blocks) — the scalar-prefetch operand that lets the
kernel resolve logical cache blocks to physical pages with no HBM gather.
Prefix sharing is invisible to the kernel: two requests whose table rows
point at the same physical page stream the same bytes the unshared layout
would, so paged output stays bit-identical.

The page count and `page_size` are DSE-tunable knobs (the `paged_decode`
kernel space in repro.autotune.kernel_tuner, whose HBM model now accounts
for shared-prefix pages); paged decode stays bit-identical to the dense
stacked path because the kernel streams the same logical blocks in the
same order — only the DMA source moves.
"""

from __future__ import annotations

import functools
import hashlib
from typing import Any, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.kernel import cdiv


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_pool_page(pool, src, dst):
    """pool[..., dst, :, :, :] = pool[..., src, :, :, :] — the device half
    of a copy-on-write split.  The page axis is always -4 ((P, ps, K, D),
    or (n, P, ps, K, D) under a scanned stack).  Donating the pool lets
    XLA update the buffer in place: O(page bytes) written, never a full
    eager copy of the pool per split."""
    return pool.at[..., dst, :, :, :].set(pool[..., src, :, :, :])


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_scale_row(scales, src, dst):
    """Scale-sidecar half of a copy-on-write split: the new private page
    keeps the donor page's quantization scales, so its already-written
    slots dequantize to the same values.  Page axis is -2 ((P, K) or
    (n, P, K))."""
    return scales.at[..., dst, :].set(scales[..., src, :])


@functools.partial(jax.jit, donate_argnums=(0,))
def _zero_scale_rows(scales, pages):
    """Pop freed pages' scale rows back to the 0.0 free-page sentinel, so a
    later re-allocation sees a fresh page (first write records its scale)."""
    return scales.at[..., pages, :].set(0.0)


class PoolExhausted(RuntimeError):
    """Raised when an alloc/grow asks for more pages than the free list holds."""


# ---------------------------------------------------------------------------
# Host-side allocator
# ---------------------------------------------------------------------------


class PagePool:
    """Refcounted free-list page allocator with per-request block tables.

    Pure host-side bookkeeping: physical page ids are ints in
    [0, num_pages); a request's block table maps logical page i (cache
    slots [i*page_size, (i+1)*page_size)) to its physical page.  The free
    list is LIFO so released pages are reused first — the pool's working
    set stays compact under admit/retire churn.

    Pages carry refcounts so several tables may map the same physical page
    (prefix sharing).  `alloc` bumps the shared prefix instead of drawing
    from the free list, `release` decrements and frees only pages whose
    count hits zero, and `cow` performs the copy-on-write *remap* half of
    a split (the device-side page copy is the manager's job).
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError(f"bad pool geometry ({num_pages=}, {page_size=})")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: list[int] = list(range(num_pages - 1, -1, -1))
        self._refs: list[int] = [0] * num_pages
        self.tables: dict[Any, list[int]] = {}
        self.peak_live = 0    # max distinct pages ever allocated at once
        self.peak_mapped = 0  # max table entries (counting shares) at once

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        """Distinct physical pages in use (shared pages count once)."""
        return self.num_pages - len(self._free)

    @property
    def mapped_pages(self) -> int:
        """Total table entries — what an unshared pool would have to hold."""
        return sum(len(t) for t in self.tables.values())

    def refcount(self, page: int) -> int:
        return self._refs[page]

    def pages_for(self, length: int) -> int:
        """Pages needed to back `length` cache slots."""
        return cdiv(max(int(length), 0), self.page_size)

    def _bump_peaks(self) -> None:
        self.peak_live = max(self.peak_live, self.live_pages)
        self.peak_mapped = max(self.peak_mapped, self.mapped_pages)

    def alloc(self, rid, n_pages: int, *,
              shared: Sequence[int] = ()) -> list[int]:
        """Allocate a table of `n_pages` pages: the `shared` prefix maps
        existing live pages (refcount bump — no free pages consumed), the
        remainder comes fresh off the free list."""
        if rid in self.tables:
            raise KeyError(f"request {rid!r} already holds pages")
        shared = list(shared)
        if len(shared) > n_pages:
            raise ValueError(
                f"shared prefix ({len(shared)}) exceeds table ({n_pages})")
        for p in shared:
            if not (0 <= p < self.num_pages) or self._refs[p] <= 0:
                raise ValueError(f"page {p} is not live — stale prefix share")
        need = n_pages - len(shared)
        if need > len(self._free):
            raise PoolExhausted(
                f"need {need} pages, {len(self._free)} free")
        for p in shared:
            self._refs[p] += 1
        fresh = [self._free.pop() for _ in range(need)]
        for p in fresh:
            self._refs[p] = 1
        self.tables[rid] = shared + fresh
        self._bump_peaks()
        return list(self.tables[rid])

    def grow_to(self, rid, n_pages: int) -> list[int]:
        """Contiguous-tail growth: append pages until the table covers
        n_pages logical pages.  Returns the newly appended physical ids."""
        table = self.tables[rid]
        need = n_pages - len(table)
        if need <= 0:
            return []
        if need > len(self._free):
            raise PoolExhausted(
                f"grow {rid!r} needs {need} pages, {len(self._free)} free")
        new = [self._free.pop() for _ in range(need)]
        for p in new:
            self._refs[p] = 1
        table.extend(new)
        self._bump_peaks()
        return new

    def release(self, rid) -> list[int]:
        """Drop the request's references; returns the pages actually freed
        (refcount hit zero) — shared pages stay live for their co-owners."""
        pages = self.tables.pop(rid)
        freed = []
        # reversed: LIFO reuse hands back the request's pages tail-first
        for p in reversed(pages):
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed

    def truncate(self, rid, n_pages: int) -> list[int]:
        """Misprediction rollback: drop the request's table entries beyond
        `n_pages`, tail-first.  Each released page is an O(1) refcount
        decrement — pages hitting zero return to the free list, shared
        (donor) pages just lose this request's reference and their bytes
        are never touched or copied.  Returns the pages actually freed."""
        if n_pages < 0:
            raise ValueError(f"cannot truncate to {n_pages} pages")
        table = self.tables[rid]
        freed = []
        while len(table) > n_pages:
            p = table.pop()
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed

    def cow(self, rid, logical: int) -> tuple[int, int] | None:
        """Copy-on-write remap: if the request's `logical` table entry is
        shared (refcount > 1), take a fresh page, point the table at it and
        drop one reference on the original.  Returns (old, new) physical
        ids for the caller to copy device-side, or None when the page was
        already exclusive."""
        table = self.tables[rid]
        old = table[logical]
        if self._refs[old] <= 1:
            return None
        if not self._free:
            raise PoolExhausted(
                f"copy-on-write split for {rid!r} needs a free page")
        new = self._free.pop()
        self._refs[new] = 1
        self._refs[old] -= 1
        table[logical] = new
        self._bump_peaks()
        return old, new

    def table_rows(self, rids: Iterable[Any], width: int) -> np.ndarray:
        """(B, width) int32 block tables, unallocated tail entries 0 (a
        valid page id: dead blocks may DMA it, never enter the math)."""
        rids = list(rids)
        rows = np.zeros((len(rids), width), np.int32)
        for i, rid in enumerate(rids):
            table = self.tables[rid]
            if len(table) > width:
                raise ValueError(
                    f"table of {rid!r} ({len(table)}) exceeds width {width}")
            rows[i, : len(table)] = table
        return rows


# ---------------------------------------------------------------------------
# Device-side paged cache manager
# ---------------------------------------------------------------------------


def _is_kv_group(value: Any) -> bool:
    return isinstance(value, dict) and "k" in value and "v" in value \
        and "ck" not in value


def paged_compatible(cache: dict) -> bool:
    """True when every stateful leaf group of a per-request decode cache is
    an attention KV cache — the families the paged pool can host.  SSM /
    recurrent states (rwkv, rglru) and cross-attention caches keep the
    dense stacked layout (`stack_request_caches`)."""
    if not isinstance(cache, dict):
        return False
    seen_kv = False
    for name, value in cache.items():
        if name == "kv_pos" or value is None:
            continue
        if not _is_kv_group(value):
            return False
        seen_kv = True
    return seen_kv


def _prefix_digests(toks: np.ndarray, page_size: int):
    """(per-boundary digests, whole-prompt digest) of a token sequence —
    the prefix-index key material.  One incremental blake2b fed page by
    page (each boundary digest covers tokens[0 : (i+1)*page_size], the
    tail digest the whole prompt), so hashing a prompt is O(S) bytes, not
    O(S^2 / page_size)."""
    data = np.ascontiguousarray(toks, np.int64).tobytes()
    stride = page_size * 8  # int64 token bytes per page
    h = hashlib.blake2b(digest_size=16)
    bounds = []
    for i in range(len(toks) // page_size):
        h.update(data[i * stride: (i + 1) * stride])
        bounds.append(h.copy().digest())
    h.update(data[len(bounds) * stride:])
    return bounds, h.digest()


class PagedCacheManager:
    """Owns the per-layer page pools + per-request paged cache state.

    One manager serves one `Server.serve_continuous` call (or a test's
    hand-driven decode loop).  Two admission paths exist:

      * the legacy `admit` packs an already-built per-request prefill
        cache into freshly allocated pages (kept for tests and callers
        with dense caches in hand);
      * the direct-to-pool path — `init_structure` (from a 1-token probe
        cache) then `match_prefix` / `admit_begin` / `admit_finish` (or
        `admit_shared` + `rescore_view` on a full-prompt prefix hit) —
        lets the model's paged-prefill branch scatter K/V straight into
        pool pages, so admission never materializes a dense max_len cache.

    `batch` re-forms the decode cache for the currently active requests
    (growing tail pages for the token about to be written and splitting
    shared pages copy-on-write first), `absorb` stores the post-step state
    back, and `retire` returns the request's references to the pool.
    """

    def __init__(self, num_pages: int, page_size: int, *,
                 max_len: int | None = None, window: int | None = None,
                 prefix_sharing: bool = True,
                 cache_dtype: str | None = None):
        from repro.kernels.flash_attention.ops import resolve_cache_dtype

        self.pool = PagePool(num_pages, page_size)
        self.page_size = page_size
        self.max_len = max_len          # logical linear-cache capacity
        self.window = window            # model's sliding/local window
        self.prefix_sharing = prefix_sharing
        # quantized pool storage ("int8" / "float8_*"): pk/pv at the narrow
        # dtype plus fp32 per-page-per-head scale sidecars.  Unknown / fp
        # names resolve to None — the pool stays at the model dtype.
        self.cache_dtype = resolve_cache_dtype(cache_dtype)
        self._pools: dict[str, dict[str, jax.Array]] = {}
        self._groups: dict[str, dict[str, Any]] = {}  # structure, 1st admit
        self._meta: dict[Any, dict[str, Any]] = {}    # per-request state
        # prefix index: token-prefix digest (per page boundary) -> physical
        # page.  "full" keys freeze at page boundaries and never go stale
        # while the page lives (decode writes land strictly past every
        # registered prefix); "tail" keys map a whole prompt's straddling
        # partial page — valid because sharers mask slots >= their own
        # length, and any write into the page splits it copy-on-write.
        self._prefix_index: dict[tuple, int] = {}
        self._page_keys: dict[int, list[tuple]] = {}
        # one-entry match memo: can_admit and the admission that follows
        # probe the same prompt back to back — invalidated whenever the
        # index mutates (_register_prefix / _purge_keys)
        self._match_cache: tuple[bytes, list[int], int] | None = None
        self.prefix_hits = 0  # pages mapped shared at admission
        self.cow_splits = 0   # copy-on-write page splits performed

    # -- admission -------------------------------------------------------------

    @property
    def has_structure(self) -> bool:
        return bool(self._groups)

    def _slots_needed(self, length: int, *,
                      prompt_len: int | None = None) -> int:
        """Worst-case pages to back `length` slots across all groups (ring
        groups clamp to their window — the slot space wraps there).  Before
        the structure is known, clamp by the configured capacity — and by
        the window when `prompt_len` says the request will ring — so
        admission control works on the very first request too."""
        if self._groups:
            return max(
                self.pool.pages_for(min(length, info["length"]))
                for info in self._groups.values()
            )
        if self.max_len is not None:
            length = min(length, self.max_len)
        if (self.window is not None and prompt_len is not None
                and prompt_len > self.window):
            length = min(length, self.window)
        return self.pool.pages_for(length)

    def _linear_len(self) -> int | None:
        lens = [info["length"] for info in self._groups.values()
                if not info["ring"]]
        return max(lens) if lens else None

    def _ring_pool(self) -> bool:
        return any(info["ring"] for info in self._groups.values())

    def _cow_exposure(self, rid) -> int:
        """Shared pages this request may still have to split: table entries
        with refcount > 1 inside its remaining write range."""
        if not self.prefix_sharing or self._ring_pool():
            return 0
        m = self._meta[rid]
        table = self.pool.tables.get(rid)
        if table is None:
            return 0
        lo = m["length"] // self.page_size
        hi = min(self._slots_needed(m["final_len"]), len(table))
        return sum(1 for i in range(lo, hi)
                   if self.pool.refcount(table[i]) > 1)

    def can_admit(self, final_len: int, tokens=None) -> bool:
        """Admission control: free pages must cover this request's worst
        case — *new* pages only: a matched prompt prefix rides on shared
        pages, plus one page if its shared tail may need a copy-on-write
        split — plus every active request's outstanding growth and
        copy-on-write exposure, so decode never hits PoolExhausted
        mid-flight.  Works before the first admission too: the structure-
        free path derives slots-per-token from the configured capacity
        (and the window, when the prompt rings)."""
        prompt_len = (len(np.asarray(tokens).reshape(-1))
                      if tokens is not None else None)
        need = self._slots_needed(final_len, prompt_len=prompt_len)
        if tokens is not None and self._groups:
            pages, shared_len = self.match_prefix(tokens)
            need -= len(pages)
            if shared_len and (shared_len % self.page_size
                               or shared_len >= prompt_len):
                # a shared tail page may split copy-on-write later — and a
                # full-prompt hit may be trimmed back to a suffix prefill
                # (long prompts; see Server._paged_admit), costing one
                # fresh page the share would otherwise have covered
                need += 1
        reserved = sum(
            self._slots_needed(m["final_len"]) - len(self.pool.tables[rid])
            + self._cow_exposure(rid)
            for rid, m in self._meta.items()
        )
        return self.pool.free_pages - reserved >= need

    def _scan_structure(self, cache: dict, *, ring: bool | None = None,
                        length: int | None = None) -> None:
        if not paged_compatible(cache):
            raise ValueError(
                "cache has non-KV state groups; paged serving supports "
                "attention-cache models — use Server.serve_batch")
        for name, value in cache.items():
            if name == "kv_pos" or value is None:
                continue
            k = value["k"]
            scanned = k.ndim == 5  # (n, 1, T, K, D) under a scanned stack
            is_ring = ("pos" in value) if ring is None else ring
            self._groups[name] = {
                "scanned": scanned,
                "n": k.shape[0] if scanned else None,
                "ring": is_ring,
                # W (ring) or max_len (linear); an explicit override wins —
                # the probe path scans a 1-token cache whose shapes say
                # nothing about capacity
                "length": length if length is not None else k.shape[-3],
                "kv_heads": k.shape[-2],
                "head_dim": k.shape[-1],
                "dtype": k.dtype,
            }

    def init_structure(self, probe_cache: dict, *, ring: bool = False) -> None:
        """Learn the pool structure (groups, dtypes, head shapes) from a
        1-token probe prefill cache and build the page pools — the
        direct-to-pool admission path's replacement for scanning a full
        dense prefill.  `ring` declares the cache family the *first real
        request* will pack (prompt longer than the window rings)."""
        if self._groups:
            raise RuntimeError("pool structure already initialised")
        if self.max_len is None:
            raise ValueError("init_structure needs the manager's max_len")
        if ring and self.window is None:
            raise ValueError("ring structure needs the manager's window")
        length = min(self.window, self.max_len) if ring else self.max_len
        self._scan_structure(probe_cache, ring=ring, length=length)
        self._ensure_pools(self.pool.num_pages)

    def _quant_dtype(self, info):
        """Pool storage dtype override for a group, or None to stay fp.
        Ring groups never quantize: the wrap rewrites page-interior slots,
        which breaks the fixed first-write page-scale policy."""
        if self.cache_dtype is None or info["ring"]:
            return None
        return self.cache_dtype

    def _ensure_pools(self, num_pages: int) -> None:
        ps = self.page_size
        for name, info in self._groups.items():
            if name in self._pools:
                continue
            qdt = self._quant_dtype(info)
            shape = (num_pages, ps, info["kv_heads"], info["head_dim"])
            sshape = (num_pages, info["kv_heads"])
            if info["scanned"]:
                shape = (info["n"], *shape)
                sshape = (info["n"], *sshape)
            pools = {
                "pk": jnp.zeros(shape, qdt or info["dtype"]),
                "pv": jnp.zeros(shape, qdt or info["dtype"]),
            }
            if qdt is not None:
                # fp32 per-page-per-head dequant scales; 0.0 = free page
                pools["ksc"] = jnp.zeros(sshape, jnp.float32)
                pools["vsc"] = jnp.zeros(sshape, jnp.float32)
            self._pools[name] = pools

    @property
    def table_width(self) -> int:
        ps = self.page_size
        return max(cdiv(info["length"], ps) for info in self._groups.values())

    # -- prefix sharing ---------------------------------------------------------

    def match_prefix(self, tokens) -> tuple[list[int], int]:
        """Longest registered prefix of `tokens` already resident in the
        pool: ([physical pages], shared slot count).  Full pages chain at
        page boundaries; a whole-prompt match may extend onto the donor's
        partial tail page (shared_len == len(tokens) — the rescore path).
        Ring pools never share (slot contents depend on the wrap)."""
        if not self.prefix_sharing or not self._groups or self._ring_pool():
            return [], 0
        toks = np.asarray(tokens, np.int64).reshape(-1)
        S = len(toks)
        lin = self._linear_len()
        if lin is None or S > lin:
            return [], 0
        key = toks.tobytes()
        if self._match_cache is not None and self._match_cache[0] == key:
            return list(self._match_cache[1]), self._match_cache[2]
        ps = self.page_size
        bounds, whole = _prefix_digests(toks, ps)
        pages: list[int] = []
        for i, digest in enumerate(bounds):
            page = self._prefix_index.get(("full", i, digest))
            if page is None:
                break
            pages.append(page)
        shared_len = len(pages) * ps
        if len(pages) == len(bounds) and S % ps:
            page = self._prefix_index.get(("tail", S, whole))
            if page is not None:
                pages.append(page)
                shared_len = S
        self._match_cache = (key, list(pages), shared_len)
        return pages, shared_len

    def _register_prefix(self, rid, tokens) -> None:
        if not self.prefix_sharing or self._ring_pool():
            return
        toks = np.asarray(tokens, np.int64).reshape(-1)
        S = len(toks)
        table = self.pool.tables[rid]
        ps = self.page_size
        self._match_cache = None

        def put(key, page):
            if key in self._prefix_index:
                return
            self._prefix_index[key] = page
            self._page_keys.setdefault(page, []).append(key)

        bounds, whole = _prefix_digests(toks, ps)
        for i in range(min(len(bounds), len(table))):
            put(("full", i, bounds[i]), table[i])
        if S % ps and S // ps < len(table):
            put(("tail", S, whole), table[S // ps])

    def _purge_keys(self, pages: Iterable[int]) -> None:
        for page in pages:
            keys = self._page_keys.pop(page, ())
            if keys:
                self._match_cache = None
            for key in keys:
                if self._prefix_index.get(key) == page:
                    del self._prefix_index[key]

    # -- direct-to-pool admission ------------------------------------------------

    def _check_family(self, prompt_len: int) -> None:
        ring_req = self.window is not None and self.window < prompt_len
        if ring_req != self._ring_pool():
            raise ValueError(
                f"request cache family mismatch (ring={ring_req}, "
                f"len={prompt_len}) vs the pool's "
                f"(ring={self._ring_pool()}); sliding-window serving needs "
                "prompts on one side of the window — use serve_batch "
                "otherwise")

    def _new_meta(self, rid, prompt_len: int, final_len: int) -> None:
        meta: dict[str, Any] = {
            "length": int(prompt_len),
            "final_len": int(final_len),
            "pos": {},
        }
        lin = self._linear_len()
        if lin is not None:
            ar = jnp.arange(lin, dtype=jnp.int32)
            meta["kv_pos"] = jnp.where(ar < prompt_len, ar, -1)
        self._meta[rid] = meta

    def _table_row(self, rid) -> jax.Array:
        return jnp.asarray(self.pool.table_rows([rid], self.table_width))

    def admit_begin(self, rid, tokens, *, final_len: int,
                    shared_pages: Sequence[int] = (),
                    shared_len: int = 0):
        """Allocate the block table (shared prompt prefix + fresh pages)
        and return the paged *prefill* cache view the model scatters the
        non-shared suffix into, plus the static prefix length.

        `final_len` is the most cache slots this request will ever occupy
        (prompt + decode budget), reserved for deadlock-free growth.
        """
        if not self._groups:
            raise RuntimeError("init_structure (or admit) must run first")
        toks = np.asarray(tokens, np.int64).reshape(-1)
        S = len(toks)
        self._check_family(S)
        start = shared_len
        if start >= S:
            raise ValueError("full-prompt prefix hits go through admit_shared")
        if start and (shared_len % self.page_size
                      or len(shared_pages) * self.page_size != shared_len):
            raise ValueError("partial shared prefixes must be page-aligned")
        lin = self._linear_len()
        if not self._ring_pool() and lin is not None and S > lin:
            raise ValueError(
                f"prompt ({S} tokens) exceeds the pool's linear capacity "
                f"({lin}) — raise max_cache_len")
        table = self.pool.alloc(rid, self._slots_needed(S),
                                shared=shared_pages)
        self.prefix_hits += len(shared_pages)
        self._new_meta(rid, S, final_len)
        return self.prefill_view(rid, start), start

    def prefill_view(self, rid, resident: int) -> dict:
        """Single-request paged *prefill* cache view with ``index`` pinned
        at `resident` tokens already pool-written — the view `admit_begin`
        hands a fresh admission (resident = shared prefix length) and the
        chunked-prefill loop re-requests between chunks (resident = last
        chunk boundary).  Chunk boundaries must stay page-aligned: a
        quantized page's scale is fixed by its first write, so every page
        must be written by exactly one prefill dispatch for the pool bytes
        to match a one-shot prefill bit-for-bit.
        """
        view: dict[str, Any] = {}
        for name, info in self._groups.items():
            group: dict[str, Any] = dict(self._pools[name])
            idx = np.full((1,), resident, np.int32)
            if info["scanned"]:
                group["index"] = jnp.asarray(np.tile(idx, (info["n"], 1)))
            else:
                group["index"] = jnp.asarray(idx)
            if info["ring"]:
                W = info["length"]
                shape = (info["n"], W) if info["scanned"] else (W,)
                pos = self._meta.get(rid, {}).get("pos", {}).get(name)
                group["pos"] = jnp.full(shape, -1, jnp.int32) \
                    if pos is None else pos
            view[name] = group
        view["block_tables"] = self._table_row(rid)
        return view

    def absorb_prefill(self, rid, new_cache: dict) -> None:
        """Absorb one prefill *chunk*'s pool writes (pk/pv plus the scale
        sidecars, ring write positions) without registering the prompt —
        `admit_finish` runs once, on the final chunk, when every prompt
        page holds its bytes."""
        meta = self._meta[rid]
        for name, info in self._groups.items():
            group = new_cache[name]
            self._pools[name] = self._pool_state(group)
            if info["ring"]:
                meta["pos"][name] = group["pos"]  # (W,) or (n, W)

    def admit_finish(self, rid, new_cache: dict, tokens) -> None:
        """Absorb the paged-prefill step's outputs (pools now hold the
        suffix K/V) and register the prompt in the prefix index."""
        self.absorb_prefill(rid, new_cache)
        self._register_prefix(rid, tokens)

    @staticmethod
    def _pool_state(group: dict) -> dict:
        """The shared pool arrays a step hands back: pk/pv plus the scale
        sidecars when the group is quantized."""
        state = {"pk": group["pk"], "pv": group["pv"]}
        for key in ("ksc", "vsc"):
            if key in group:
                state[key] = group[key]
        return state

    def admit_shared(self, rid, tokens, *, final_len: int,
                     pages: Sequence[int]) -> None:
        """Admit a full-prompt prefix hit: every prompt page is already
        resident, no prefill runs — the caller re-scores the last prompt
        token (`rescore_view`) for its first output logits.  The first
        decode write into the shared tail page splits it copy-on-write."""
        if not self._groups:
            raise RuntimeError("init_structure (or admit) must run first")
        toks = np.asarray(tokens, np.int64).reshape(-1)
        S = len(toks)
        self._check_family(S)
        if len(pages) != self._slots_needed(S):
            raise ValueError(
                f"full-prompt share needs {self._slots_needed(S)} pages, "
                f"got {len(pages)}")
        self.pool.alloc(rid, len(pages), shared=pages)
        self.prefix_hits += len(pages)
        self._new_meta(rid, S, final_len)

    def rescore_view(self, rid) -> dict:
        """Single-request decode cache view with index = length - 1: the
        no-write re-score of the last prompt token that yields a shared-
        admission's first output logits."""
        return self._compose([rid], index_offset=-1)

    # -- legacy admission (pack an existing dense prefill cache) -----------------

    def admit(self, rid, cache: dict, *, final_len: int) -> None:
        """Pack a per-request (batch=1) prefill cache into pool pages.

        `final_len` is the most cache slots this request will ever occupy
        (prompt + decode budget), reserved for deadlock-free growth.
        """
        if not self._groups:
            self._scan_structure(cache)
            self._ensure_pools(self.pool.num_pages)
        else:
            # every request must pack the same cache family per group:
            # Attention._build_cache rings only when window < prompt_len,
            # so a sliding-window batch straddling W would otherwise mix
            # ring and linear layouts in one pool — refuse loudly.
            for name, info in self._groups.items():
                group = cache[name]
                if ("pos" in group) != info["ring"] \
                        or group["k"].shape[-3] != info["length"]:
                    raise ValueError(
                        f"request cache family mismatch in group {name!r} "
                        f"(ring={'pos' in group}, "
                        f"len={group['k'].shape[-3]}) vs the pool's "
                        f"(ring={info['ring']}, len={info['length']}); "
                        "sliding-window serving needs prompts on one side "
                        "of the window — use serve_batch otherwise")
        ps = self.page_size
        length = None
        for name, info in self._groups.items():
            idx = cache[name]["index"]
            length = int(np.asarray(idx).reshape(-1)[0])
            break
        pages = self.pool.alloc(rid, self._slots_needed(length))
        pages_arr = np.asarray(pages, np.int32)

        for name, info in self._groups.items():
            group = cache[name]
            for src_key, dst_key in (("k", "pk"), ("v", "pv")):
                arr = group[src_key]
                if info["scanned"]:
                    arr = arr[:, 0]  # (n, T, K, D)
                else:
                    arr = arr[0]     # (T, K, D)
                need = len(pages) * ps
                T = arr.shape[-3]
                if need > T:
                    pad = [(0, 0)] * arr.ndim
                    pad[-3] = (0, need - T)
                    arr = jnp.pad(arr, pad)
                else:
                    arr = arr[..., :need, :, :]
                paged = arr.reshape(*arr.shape[:-3], len(pages), ps,
                                    *arr.shape[-2:])
                pools = self._pools[name]
                sc_key = {"pk": "ksc", "pv": "vsc"}[dst_key]
                if sc_key in pools:
                    from repro.kernels.flash_attention.ops import (
                        kv_scale_from_absmax,
                        quantize_kv_write,
                    )

                    # per-page-per-head absmax; the zero padding past the
                    # prompt neither raises it nor survives dequant
                    scale = kv_scale_from_absmax(
                        jnp.max(jnp.abs(paged.astype(jnp.float32)),
                                axis=(-3, -1)),
                        pools[dst_key].dtype)
                    paged = quantize_kv_write(paged, scale[..., None, :],
                                              pools[dst_key].dtype)
                    sc = pools[sc_key]
                    if info["scanned"]:
                        sc = sc.at[:, pages_arr].set(scale)
                    else:
                        sc = sc.at[pages_arr].set(scale)
                    pools[sc_key] = sc
                pool = pools[dst_key]
                if info["scanned"]:
                    pool = pool.at[:, pages_arr].set(paged)
                else:
                    pool = pool.at[pages_arr].set(paged)
                pools[dst_key] = pool

        meta: dict[str, Any] = {
            "length": length,
            "final_len": int(final_len),
            "pos": {},
        }
        for name, info in self._groups.items():
            if info["ring"]:
                meta["pos"][name] = cache[name]["pos"]  # (W,) or (n, W)
        if "kv_pos" in cache:
            meta["kv_pos"] = cache["kv_pos"][0]  # (max_len,)
        self._meta[rid] = meta

    def retire(self, rid) -> None:
        freed = self.pool.release(rid)
        self._purge_keys(freed)
        self._pop_scales(freed)
        del self._meta[rid]

    def abort(self, rid) -> None:
        """Best-effort rollback of a partial admission (or a forced
        eviction): release the request's pages if it holds any and drop
        its meta — idempotent, so fault-isolation paths can call it
        without knowing how far the admission got.  Freed pages leave the
        prefix index and their scale-sidecar rows reset to the free-page
        sentinel, exactly as `retire` would."""
        if rid in self.pool.tables:
            freed = self.pool.release(rid)
            self._purge_keys(freed)
            self._pop_scales(freed)
        self._meta.pop(rid, None)

    def _pop_scales(self, freed: Sequence[int]) -> None:
        """Reset freed pages' sidecar rows to the free-page sentinel: a
        page's scale lives exactly as long as the page does."""
        if not freed:
            return
        idx = jnp.asarray(list(freed), jnp.int32)
        for name in self._groups:
            pools = self._pools.get(name)
            if pools and "ksc" in pools:
                pools["ksc"] = _zero_scale_rows(pools["ksc"], idx)
                pools["vsc"] = _zero_scale_rows(pools["vsc"], idx)

    # -- per-step batch composition ---------------------------------------------

    def _cow_for_write(self, rid, tokens: int = 1) -> None:
        """Split every shared page the request's next `tokens` decode slots
        would write: copy page -> remap table -> (the step then) writes.
        Runs before the decode step so the scatter lands in the private
        copies and shared pages are never mutated."""
        if not self.prefix_sharing or self._ring_pool():
            return
        m = self._meta[rid]
        start = m["length"]
        stop = start + tokens
        lin = self._linear_len()
        if lin is not None:
            stop = min(stop, lin)  # past-the-end writes are dropped
        if stop <= start:
            return
        table = self.pool.tables[rid]
        for pidx in range(start // self.page_size,
                          min(cdiv(stop, self.page_size), len(table))):
            split = self.pool.cow(rid, pidx)
            if split is None:
                continue
            old, new = split
            for name in self._groups:
                pools = self._pools[name]
                for key in ("pk", "pv"):
                    pools[key] = _copy_pool_page(pools[key], old, new)
                if "ksc" in pools:
                    # private copy dequantizes identically to the donor
                    pools["ksc"] = _copy_scale_row(pools["ksc"], old, new)
                    pools["vsc"] = _copy_scale_row(pools["vsc"], old, new)
            self.cow_splits += 1

    def batch(self, rids: list[Any], *, tokens: int = 1) -> dict:
        """Decode cache pytree for this step's active set, in `rids` order.

        Grows each request's tail pages to cover the `tokens` slots the
        step writes (tokens > 1: the speculative verify step's draft block)
        — clamped at the reserved `final_len`, so growth can never outrun
        the admission-time reservation — splits shared pages the step would
        write (copy-on-write), then stacks the per-request rows around the
        shared pools.
        """
        for rid in rids:
            m = self._meta[rid]
            target = min(m["length"] + tokens, m["final_len"])
            self.pool.grow_to(rid, self._slots_needed(target))
            self._cow_for_write(rid, tokens)
        return self._compose(rids)

    def _compose(self, rids: list[Any], *, index_offset: int = 0) -> dict:
        lengths = np.asarray(
            [self._meta[r]["length"] + index_offset for r in rids], np.int32)
        tables = jnp.asarray(self.pool.table_rows(rids, self.table_width))

        cache: dict[str, Any] = {}
        for name, info in self._groups.items():
            group: dict[str, Any] = dict(self._pools[name])
            if info["scanned"]:
                group["index"] = jnp.asarray(
                    np.tile(lengths, (info["n"], 1)))
            else:
                group["index"] = jnp.asarray(lengths)
            if info["ring"]:
                rows = [self._meta[r]["pos"][name] for r in rids]
                group["pos"] = jnp.stack(rows,
                                         axis=1 if info["scanned"] else 0)
            cache[name] = group
        cache["block_tables"] = tables
        if any("kv_pos" in self._meta[r] for r in rids):
            rows = []
            for r in rids:
                kvp = self._meta[r].get("kv_pos")
                if kvp is None:
                    # a legacy admit() of a hand-built cache may lack the
                    # hoisted map; synthesize it (slot s -> s while live —
                    # exactly what the decode steps would have maintained)
                    width = self._linear_len() or self.max_len
                    ar = jnp.arange(int(width), dtype=jnp.int32)
                    kvp = jnp.where(ar < self._meta[r]["length"], ar, -1)
                    self._meta[r]["kv_pos"] = kvp
                rows.append(kvp)
            cache["kv_pos"] = jnp.stack(rows, axis=0)
        return cache

    def absorb(self, rids: list[Any], new_cache: dict, *,
               advance: int = 1) -> None:
        """Store one decode step's outputs back: pools are shared (one
        assignment), per-request rows split on their batch axis.  A
        speculative verify step passes `advance` = its q span so lengths
        provisionally cover the whole draft block (rollback() then trims
        rejected tokens)."""
        for name, info in self._groups.items():
            group = new_cache[name]
            self._pools[name] = self._pool_state(group)
            if info["ring"]:
                axis = 1 if info["scanned"] else 0
                for i, rid in enumerate(rids):
                    self._meta[rid]["pos"][name] = jnp.take(
                        group["pos"], i, axis=axis)
        if "kv_pos" in new_cache:
            for i, rid in enumerate(rids):
                self._meta[rid]["kv_pos"] = new_cache["kv_pos"][i]
        for rid in rids:
            self._meta[rid]["length"] += advance

    def rollback(self, rid, new_length: int) -> list[int]:
        """Speculative-misprediction rollback: shrink the request to
        `new_length` live tokens in O(1) pool operations per tail page.

        Table entries past the slots `new_length` needs are released
        tail-first (refcount decrement — donor pages shared with other
        requests just lose this reference, their bytes are never touched
        or copied), freed pages are purged from the prefix index, and the
        hoisted `kv_pos` map is rewound so stale draft slots mask dead.
        The over-written K/V bytes in still-held pages are left in place:
        they sit past the live boundary, so attention never reads them and
        the next decode step overwrites them.  CoW splits performed for
        the rejected write are *not* undone — the private copy holds the
        request's valid prefix slots.  Returns the pages actually freed.
        """
        m = self._meta[rid]
        if new_length < 0 or new_length > m["length"]:
            raise ValueError(
                f"rollback({rid!r}) to {new_length} outside [0, "
                f"{m['length']}]")
        m["length"] = new_length
        freed = self.pool.truncate(rid, self._slots_needed(new_length))
        if freed:
            self._purge_keys(freed)
            self._pop_scales(freed)
        if "kv_pos" in m:
            kvp = m["kv_pos"]
            ar = jnp.arange(kvp.shape[-1], dtype=jnp.int32)
            m["kv_pos"] = jnp.where(ar < new_length, kvp, -1)
        return freed

    # -- introspection -----------------------------------------------------------

    def _group_page_bytes(self, name: str, info: dict) -> int:
        """Per-live-page bytes of one group across its layers: quantized
        payload at the *pool* dtype plus the fp32 scale sidecar rows."""
        pools = self._pools.get(name)
        qdt = self._quant_dtype(info)
        dtype = pools["pk"].dtype if pools else (qdt or info["dtype"])
        quantized = ("ksc" in pools) if pools else qdt is not None
        per_page = 2 * (self.page_size * info["kv_heads"] * info["head_dim"]
                        * np.dtype(dtype).itemsize)
        if quantized:
            per_page += 2 * info["kv_heads"] * 4  # k + v fp32 scale rows
        layers = info["n"] if info["scanned"] else 1
        return layers * per_page

    def hbm_pool_bytes(self) -> int:
        """Allocated KV bytes: *distinct* live pages across every layer
        pool — shared prefix pages count once, quantized pools count their
        narrow payload plus scale sidecars."""
        return sum(self._group_page_bytes(name, info) * self.pool.live_pages
                   for name, info in self._groups.items())

    def stats(self) -> dict[str, Any]:
        """Pool economics snapshot: distinct vs mapped pages (the gap is
        the prefix-sharing saving), peak values, hit/split counters, and
        the dtype-aware pool HBM footprint (benches consume these instead
        of recomputing bytes by hand)."""
        bytes_now = self.hbm_pool_bytes()
        page_bytes = sum(self._group_page_bytes(name, info)
                         for name, info in self._groups.items())
        return {
            "num_pages": self.pool.num_pages,
            "page_size": self.page_size,
            "live_pages": self.pool.live_pages,
            "mapped_pages": self.pool.mapped_pages,
            "peak_live_pages": self.pool.peak_live,
            "peak_mapped_pages": self.pool.peak_mapped,
            "prefix_hits": self.prefix_hits,
            "cow_splits": self.cow_splits,
            "hbm_pool_bytes": bytes_now,
            "pool_hbm_bytes": bytes_now,
            "peak_pool_hbm_bytes": page_bytes * self.pool.peak_live,
            "page_hbm_bytes": page_bytes,
            "cache_dtype": (np.dtype(self.cache_dtype).name
                            if self.cache_dtype is not None else None),
        }


# ---------------------------------------------------------------------------
# Invariant auditing (fault-isolation debug barrier)
# ---------------------------------------------------------------------------


class PoolInvariantError(RuntimeError):
    """A pool/manager invariant does not hold — state corruption caught at
    the barrier where it happened, not three steps later."""


class PoolAuditor:
    """Invariant checker over a PagePool (and optionally the manager that
    owns it).  Run at retire/rollback barriers under the `pool_audit`
    debug knob: every check is host-side bookkeeping except the
    scale-sidecar sentinel check, which is gated separately because it
    forces a device transfer.

    Invariants:
      * refcount conservation — every page's refcount equals the number
        of table entries mapping it, across all live tables;
      * free/referenced disjointness — no page is both on the free list
        and referenced (and the free list holds no duplicates);
      * conservation — free + distinct referenced pages partition the
        pool exactly;
      * table liveness — every table entry is a valid page id with
        refcount >= 1, and no table maps the same page at two logical
        positions;
      * manager consistency — tables and per-request meta cover the same
        request ids, each table spans the pages its live length needs and
        never exceeds its `final_len` reservation, and every prefix-index
        entry points at a live page;
      * scale-sidecar consistency (`check_device=True`) — free pages'
        quantization scale rows sit at the 0.0 free-page sentinel.
    """

    def __init__(self, target: "PagePool | PagedCacheManager", *,
                 check_device: bool = False):
        if isinstance(target, PagedCacheManager):
            self.manager: PagedCacheManager | None = target
            self.pool = target.pool
        else:
            self.manager = None
            self.pool = target
        self.check_device = check_device

    def _fail(self, violations: list[str]) -> None:
        if violations:
            raise PoolInvariantError(
                "pool invariant violation(s): " + "; ".join(violations))

    def audit(self) -> dict[str, Any]:
        """Check every invariant; raises PoolInvariantError on the first
        audit with violations, returns a summary dict otherwise."""
        pool = self.pool
        bad: list[str] = []
        free = list(pool._free)
        free_set = set(free)
        if len(free) != len(free_set):
            bad.append("free list holds duplicate pages")
        mapped: dict[int, int] = {}
        for rid, table in pool.tables.items():
            seen_here: set[int] = set()
            for logical, p in enumerate(table):
                if not (0 <= p < pool.num_pages):
                    bad.append(f"table {rid!r}[{logical}] = {p} out of range")
                    continue
                if p in seen_here:
                    bad.append(f"table {rid!r} maps page {p} twice")
                seen_here.add(p)
                mapped[p] = mapped.get(p, 0) + 1
        for p in range(pool.num_pages):
            refs = pool._refs[p]
            n_mapped = mapped.get(p, 0)
            if refs != n_mapped:
                bad.append(
                    f"page {p}: refcount {refs} != {n_mapped} table entries")
            if p in free_set and refs > 0:
                bad.append(f"page {p} both free and referenced ({refs})")
            if p not in free_set and refs == 0:
                bad.append(f"page {p} neither free nor referenced (leak)")
        if len(free_set) + len(mapped) != pool.num_pages:
            bad.append(
                f"conservation: {len(free_set)} free + {len(mapped)} live "
                f"!= {pool.num_pages} pages")
        checks = 4
        if self.manager is not None:
            checks += self._audit_manager(bad)
        self._fail(bad)
        return {"checks": checks, "live_pages": len(mapped),
                "free_pages": len(free_set),
                "requests": len(pool.tables)}

    def _audit_manager(self, bad: list[str]) -> int:
        mgr = self.manager
        pool = self.pool
        if set(pool.tables) != set(mgr._meta):
            bad.append(
                f"tables {sorted(map(repr, pool.tables))} != meta "
                f"{sorted(map(repr, mgr._meta))}")
        for rid, meta in mgr._meta.items():
            table = pool.tables.get(rid)
            if table is None:
                continue
            if mgr._groups:
                need = mgr._slots_needed(meta["length"])
                cap = mgr._slots_needed(meta["final_len"])
                if len(table) < need:
                    bad.append(
                        f"table {rid!r} holds {len(table)} pages, live "
                        f"length {meta['length']} needs {need}")
                if len(table) > cap:
                    bad.append(
                        f"table {rid!r} holds {len(table)} pages past its "
                        f"final_len reservation ({cap})")
        for key, page in mgr._prefix_index.items():
            if not (0 <= page < pool.num_pages) or pool._refs[page] <= 0:
                bad.append(f"prefix key {key[:2]} maps dead page {page}")
        checks = 3
        if self.check_device:
            checks += self._audit_sidecars(bad)
        return checks

    def _audit_sidecars(self, bad: list[str]) -> int:
        mgr = self.manager
        free = sorted(self.pool._free)
        if not free:
            return 1
        for name in mgr._groups:
            pools = mgr._pools.get(name)
            if not pools or "ksc" not in pools:
                continue
            for key in ("ksc", "vsc"):
                rows = np.asarray(pools[key])[..., free, :]
                if np.any(rows != 0.0):
                    bad.append(
                        f"group {name!r} {key} sidecar: free pages hold "
                        "non-sentinel scales")
        return 1


def audit_pool(target, **kwargs) -> dict[str, Any]:
    """One-shot invariant audit — `PoolAuditor(target).audit()`."""
    return PoolAuditor(target, **kwargs).audit()


# ---------------------------------------------------------------------------
# Raw-array pool packing (benches / kernel-level tests)
# ---------------------------------------------------------------------------


def build_linear_pool(ks, vs, page_size: int, *, max_len: int | None = None,
                      num_pages: int | None = None):
    """Pack per-request linear cache prefixes (T_i, K, D) into one pool.

    Returns (pk, pv, tables, pool): pool arrays (P, page_size, K, D), block
    tables (B, ceil(max_len/page_size)), and the PagePool (so callers can
    inspect live pages / release).  Pure convenience for benches and tests
    that drive `flash_decode` directly without a model.
    """
    lengths = [int(k.shape[0]) for k in ks]
    max_len = max_len or max(lengths)
    need = sum(cdiv(l, page_size) for l in lengths)
    pool = PagePool(num_pages or need, page_size)
    width = cdiv(max_len, page_size)
    Kh, D = ks[0].shape[-2], ks[0].shape[-1]
    pk = np.zeros((pool.num_pages, page_size, Kh, D), np.asarray(ks[0]).dtype)
    pv = np.zeros_like(pk)
    for i, (k, v, l) in enumerate(zip(ks, vs, lengths)):
        pages = pool.alloc(i, cdiv(l, page_size))
        k, v = np.asarray(k), np.asarray(v)
        for j, p in enumerate(pages):
            sl = slice(j * page_size, min((j + 1) * page_size, l))
            pk[p, : sl.stop - sl.start] = k[sl]
            pv[p, : sl.stop - sl.start] = v[sl]
    tables = jnp.asarray(pool.table_rows(range(len(ks)), width))
    return jnp.asarray(pk), jnp.asarray(pv), tables, pool


def quantize_linear_pool(pk, pv, cache_dtype: str):
    """Quantize a `build_linear_pool` pool to (qpk, qpv, ksc, vsc): per-
    page-per-head absmax scales ((P, K) fp32, 0.0 on all-zero free pages),
    payload at the requested cache dtype.  Bench/kernel-test convenience —
    serving pools quantize at write time inside Attention."""
    from repro.kernels.flash_attention.ops import (
        kv_scale_from_absmax,
        quantize_kv_write,
        resolve_cache_dtype,
    )

    dt = resolve_cache_dtype(cache_dtype)
    if dt is None:
        raise ValueError(f"not a quantized cache dtype: {cache_dtype!r}")
    ksc = kv_scale_from_absmax(
        jnp.max(jnp.abs(jnp.asarray(pk, jnp.float32)), axis=(-3, -1)), dt)
    vsc = kv_scale_from_absmax(
        jnp.max(jnp.abs(jnp.asarray(pv, jnp.float32)), axis=(-3, -1)), dt)
    qpk = quantize_kv_write(pk, ksc[..., None, :], dt)
    qpv = quantize_kv_write(pv, vsc[..., None, :], dt)
    return qpk, qpv, ksc, vsc
