"""Paged KV-cache pool: the vLLM block-table layout for the serving runtime.

`stack_request_caches` (PR 3) batches variable-length requests by padding
every per-request cache to the same length — HBM scales with
batch x max_len even when most requests are short.  This module replaces
that with one shared pool of fixed-size pages per layer:

  PagePool            host-side free-list allocator: physical pages are
                      allocated on admission, appended at the logical tail
                      as a request's cache grows past a page boundary
                      (decode writes are strictly sequential in slot space,
                      so growth is always contiguous-tail), and released
                      when the request retires.  Admission is
                      reservation-aware: a request is only admitted when
                      the pool can cover every active request's *worst
                      case* growth, so decode can never deadlock on pages.

  PagedCacheManager   device-side owner of the per-layer page pools.  It
                      packs per-request (batch=1) prefill caches into pool
                      pages, re-forms the batched decode cache pytree for
                      whatever set of requests is active *this step*
                      (continuous batching: the batch is recomposed every
                      token), and absorbs the post-step pools / ring `pos`
                      rows / `kv_pos` rows back into per-request state.

The resulting cache pytree is what `Attention._decode`'s paged branch and
the block-table `flash_decode` kernel consume: per layer `{"pk", "pv"}`
pools of shape (P, page_size, K, D) (leading layer dim under a scanned
stack) with per-request `index`, ring `pos`, and one shared top-level
`block_tables` (B, num_blocks) — the scalar-prefetch operand that lets the
kernel resolve logical cache blocks to physical pages with no HBM gather.

The page count and `page_size` are DSE-tunable knobs (the `paged_decode`
kernel space in repro.autotune.kernel_tuner); paged decode stays
bit-identical to the dense stacked path because the kernel streams the
same logical blocks in the same order — only the DMA source moves.
"""

from __future__ import annotations

from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.kernel import cdiv


class PoolExhausted(RuntimeError):
    """Raised when an alloc/grow asks for more pages than the free list holds."""


# ---------------------------------------------------------------------------
# Host-side allocator
# ---------------------------------------------------------------------------


class PagePool:
    """Free-list page allocator with per-request block tables.

    Pure host-side bookkeeping: physical page ids are ints in
    [0, num_pages); a request's block table maps logical page i (cache
    slots [i*page_size, (i+1)*page_size)) to its physical page.  The free
    list is LIFO so released pages are reused first — the pool's working
    set stays compact under admit/retire churn.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError(f"bad pool geometry ({num_pages=}, {page_size=})")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: list[int] = list(range(num_pages - 1, -1, -1))
        self.tables: dict[Any, list[int]] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        return self.num_pages - len(self._free)

    def pages_for(self, length: int) -> int:
        """Pages needed to back `length` cache slots."""
        return cdiv(max(int(length), 0), self.page_size)

    def alloc(self, rid, n_pages: int) -> list[int]:
        if rid in self.tables:
            raise KeyError(f"request {rid!r} already holds pages")
        if n_pages > len(self._free):
            raise PoolExhausted(
                f"need {n_pages} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(n_pages)]
        self.tables[rid] = pages
        return pages

    def grow_to(self, rid, n_pages: int) -> list[int]:
        """Contiguous-tail growth: append pages until the table covers
        n_pages logical pages.  Returns the newly appended physical ids."""
        table = self.tables[rid]
        need = n_pages - len(table)
        if need <= 0:
            return []
        if need > len(self._free):
            raise PoolExhausted(
                f"grow {rid!r} needs {need} pages, {len(self._free)} free")
        new = [self._free.pop() for _ in range(need)]
        table.extend(new)
        return new

    def release(self, rid) -> list[int]:
        pages = self.tables.pop(rid)
        # reversed: LIFO reuse hands back the request's pages tail-first
        self._free.extend(reversed(pages))
        return pages

    def table_rows(self, rids: Iterable[Any], width: int) -> np.ndarray:
        """(B, width) int32 block tables, unallocated tail entries 0 (a
        valid page id: dead blocks may DMA it, never enter the math)."""
        rids = list(rids)
        rows = np.zeros((len(rids), width), np.int32)
        for i, rid in enumerate(rids):
            table = self.tables[rid]
            if len(table) > width:
                raise ValueError(
                    f"table of {rid!r} ({len(table)}) exceeds width {width}")
            rows[i, : len(table)] = table
        return rows


# ---------------------------------------------------------------------------
# Device-side paged cache manager
# ---------------------------------------------------------------------------


def _is_kv_group(value: Any) -> bool:
    return isinstance(value, dict) and "k" in value and "v" in value \
        and "ck" not in value


def paged_compatible(cache: dict) -> bool:
    """True when every stateful leaf group of a per-request decode cache is
    an attention KV cache — the families the paged pool can host.  SSM /
    recurrent states (rwkv, rglru) and cross-attention caches keep the
    dense stacked layout (`stack_request_caches`)."""
    if not isinstance(cache, dict):
        return False
    seen_kv = False
    for name, value in cache.items():
        if name == "kv_pos" or value is None:
            continue
        if not _is_kv_group(value):
            return False
        seen_kv = True
    return seen_kv


class PagedCacheManager:
    """Owns the per-layer page pools + per-request paged cache state.

    One manager serves one `Server.serve_continuous` call (or a test's
    hand-driven decode loop): `admit` packs a request's prefill cache into
    freshly allocated pages, `batch` re-forms the decode cache for the
    currently active requests (growing tail pages for the token about to
    be written), `absorb` stores the post-step state back, and `retire`
    returns the request's pages to the free list.
    """

    def __init__(self, num_pages: int, page_size: int):
        self.pool = PagePool(num_pages, page_size)
        self.page_size = page_size
        self._pools: dict[str, dict[str, jax.Array]] = {}
        self._groups: dict[str, dict[str, Any]] = {}  # structure, 1st admit
        self._meta: dict[Any, dict[str, Any]] = {}    # per-request state

    # -- admission -------------------------------------------------------------

    def _slots_needed(self, length: int) -> int:
        """Worst-case pages to back `length` slots across all groups (ring
        groups clamp to their window — the slot space wraps there)."""
        return max(
            self.pool.pages_for(min(length, info["length"]))
            for info in self._groups.values()
        )

    def can_admit(self, final_len: int) -> bool:
        """Admission control: free pages must cover this request's worst
        case *plus* every active request's outstanding growth, so decode
        never hits PoolExhausted mid-flight."""
        if not self._groups:  # first request defines the structure
            return self.pool.free_pages > 0
        reserved = sum(
            self._slots_needed(m["final_len"]) - len(self.pool.tables[rid])
            for rid, m in self._meta.items()
        )
        return (self.pool.free_pages - reserved
                >= self._slots_needed(final_len))

    def _scan_structure(self, cache: dict) -> None:
        if not paged_compatible(cache):
            raise ValueError(
                "cache has non-KV state groups; paged serving supports "
                "attention-cache models — use Server.serve_batch")
        for name, value in cache.items():
            if name == "kv_pos" or value is None:
                continue
            k = value["k"]
            scanned = k.ndim == 5  # (n, 1, T, K, D) under a scanned stack
            self._groups[name] = {
                "scanned": scanned,
                "n": k.shape[0] if scanned else None,
                "ring": "pos" in value,
                "length": k.shape[-3],  # W (ring) or max_len (linear)
                "kv_heads": k.shape[-2],
                "head_dim": k.shape[-1],
                "dtype": k.dtype,
            }

    def _ensure_pools(self, num_pages: int) -> None:
        ps = self.page_size
        for name, info in self._groups.items():
            if name in self._pools:
                continue
            shape = (num_pages, ps, info["kv_heads"], info["head_dim"])
            if info["scanned"]:
                shape = (info["n"], *shape)
            self._pools[name] = {
                "pk": jnp.zeros(shape, info["dtype"]),
                "pv": jnp.zeros(shape, info["dtype"]),
            }

    @property
    def table_width(self) -> int:
        ps = self.page_size
        return max(cdiv(info["length"], ps) for info in self._groups.values())

    def admit(self, rid, cache: dict, *, final_len: int) -> None:
        """Pack a per-request (batch=1) prefill cache into pool pages.

        `final_len` is the most cache slots this request will ever occupy
        (prompt + decode budget), reserved for deadlock-free growth.
        """
        if not self._groups:
            self._scan_structure(cache)
            self._ensure_pools(self.pool.num_pages)
        else:
            # every request must pack the same cache family per group:
            # Attention._build_cache rings only when window < prompt_len,
            # so a sliding-window batch straddling W would otherwise mix
            # ring and linear layouts in one pool — refuse loudly.
            for name, info in self._groups.items():
                group = cache[name]
                if ("pos" in group) != info["ring"] \
                        or group["k"].shape[-3] != info["length"]:
                    raise ValueError(
                        f"request cache family mismatch in group {name!r} "
                        f"(ring={'pos' in group}, "
                        f"len={group['k'].shape[-3]}) vs the pool's "
                        f"(ring={info['ring']}, len={info['length']}); "
                        "sliding-window serving needs prompts on one side "
                        "of the window — use serve_batch otherwise")
        ps = self.page_size
        length = None
        for name, info in self._groups.items():
            idx = cache[name]["index"]
            length = int(np.asarray(idx).reshape(-1)[0])
            break
        pages = self.pool.alloc(rid, self._slots_needed(length))
        pages_arr = np.asarray(pages, np.int32)

        for name, info in self._groups.items():
            group = cache[name]
            for src_key, dst_key in (("k", "pk"), ("v", "pv")):
                arr = group[src_key]
                if info["scanned"]:
                    arr = arr[:, 0]  # (n, T, K, D)
                else:
                    arr = arr[0]     # (T, K, D)
                need = len(pages) * ps
                T = arr.shape[-3]
                if need > T:
                    pad = [(0, 0)] * arr.ndim
                    pad[-3] = (0, need - T)
                    arr = jnp.pad(arr, pad)
                else:
                    arr = arr[..., :need, :, :]
                paged = arr.reshape(*arr.shape[:-3], len(pages), ps,
                                    *arr.shape[-2:])
                pool = self._pools[name][dst_key]
                if info["scanned"]:
                    pool = pool.at[:, pages_arr].set(paged)
                else:
                    pool = pool.at[pages_arr].set(paged)
                self._pools[name][dst_key] = pool

        meta: dict[str, Any] = {
            "length": length,
            "final_len": int(final_len),
            "pos": {},
        }
        for name, info in self._groups.items():
            if info["ring"]:
                meta["pos"][name] = cache[name]["pos"]  # (W,) or (n, W)
        if "kv_pos" in cache:
            meta["kv_pos"] = cache["kv_pos"][0]  # (max_len,)
        self._meta[rid] = meta

    def retire(self, rid) -> None:
        self.pool.release(rid)
        del self._meta[rid]

    # -- per-step batch composition ---------------------------------------------

    def batch(self, rids: list[Any]) -> dict:
        """Decode cache pytree for this step's active set, in `rids` order.

        Grows each request's tail pages to cover the slot its next token
        writes, then stacks the per-request rows around the shared pools.
        """
        for rid in rids:
            self.pool.grow_to(rid, self._slots_needed(
                self._meta[rid]["length"] + 1))
        lengths = np.asarray([self._meta[r]["length"] for r in rids],
                             np.int32)
        tables = jnp.asarray(self.pool.table_rows(rids, self.table_width))

        cache: dict[str, Any] = {}
        for name, info in self._groups.items():
            group: dict[str, Any] = dict(self._pools[name])
            if info["scanned"]:
                group["index"] = jnp.asarray(
                    np.tile(lengths, (info["n"], 1)))
            else:
                group["index"] = jnp.asarray(lengths)
            if info["ring"]:
                rows = [self._meta[r]["pos"][name] for r in rids]
                group["pos"] = jnp.stack(rows,
                                         axis=1 if info["scanned"] else 0)
            cache[name] = group
        cache["block_tables"] = tables
        if any("kv_pos" in self._meta[r] for r in rids):
            cache["kv_pos"] = jnp.stack(
                [self._meta[r]["kv_pos"] for r in rids], axis=0)
        return cache

    def absorb(self, rids: list[Any], new_cache: dict) -> None:
        """Store one decode step's outputs back: pools are shared (one
        assignment), per-request rows split on their batch axis."""
        for name, info in self._groups.items():
            group = new_cache[name]
            self._pools[name] = {"pk": group["pk"], "pv": group["pv"]}
            if info["ring"]:
                axis = 1 if info["scanned"] else 0
                for i, rid in enumerate(rids):
                    self._meta[rid]["pos"][name] = jnp.take(
                        group["pos"], i, axis=axis)
        if "kv_pos" in new_cache:
            for i, rid in enumerate(rids):
                self._meta[rid]["kv_pos"] = new_cache["kv_pos"][i]
        for rid in rids:
            self._meta[rid]["length"] += 1

    # -- introspection -----------------------------------------------------------

    def hbm_pool_bytes(self) -> int:
        """Allocated KV bytes: live pages across every layer pool."""
        total = 0
        for name, info in self._groups.items():
            per_page = (self.page_size * info["kv_heads"] * info["head_dim"]
                        * np.dtype(info["dtype"]).itemsize)
            layers = info["n"] if info["scanned"] else 1
            total += 2 * layers * per_page * self.pool.live_pages
        return total


# ---------------------------------------------------------------------------
# Raw-array pool packing (benches / kernel-level tests)
# ---------------------------------------------------------------------------


def build_linear_pool(ks, vs, page_size: int, *, max_len: int | None = None,
                      num_pages: int | None = None):
    """Pack per-request linear cache prefixes (T_i, K, D) into one pool.

    Returns (pk, pv, tables, pool): pool arrays (P, page_size, K, D), block
    tables (B, ceil(max_len/page_size)), and the PagePool (so callers can
    inspect live pages / release).  Pure convenience for benches and tests
    that drive `flash_decode` directly without a model.
    """
    lengths = [int(k.shape[0]) for k in ks]
    max_len = max_len or max(lengths)
    need = sum(cdiv(l, page_size) for l in lengths)
    pool = PagePool(num_pages or need, page_size)
    width = cdiv(max_len, page_size)
    Kh, D = ks[0].shape[-2], ks[0].shape[-1]
    pk = np.zeros((pool.num_pages, page_size, Kh, D), np.asarray(ks[0]).dtype)
    pv = np.zeros_like(pk)
    for i, (k, v, l) in enumerate(zip(ks, vs, lengths)):
        pages = pool.alloc(i, cdiv(l, page_size))
        k, v = np.asarray(k), np.asarray(v)
        for j, p in enumerate(pages):
            sl = slice(j * page_size, min((j + 1) * page_size, l))
            pk[p, : sl.stop - sl.start] = k[sl]
            pv[p, : sl.stop - sl.start] = v[sl]
    tables = jnp.asarray(pool.table_rows(range(len(ks)), width))
    return jnp.asarray(pk), jnp.asarray(pv), tables, pool
