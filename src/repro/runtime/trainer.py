"""Training runtime: the woven application's collect-analyse-decide-act loop.

Composes every ANTAREX runtime service around the jitted train step:
  - libVC holds one compiled executable per weave variant; the mARGOt
    autotuner (if attached) picks the variant/knobs each adaptation window;
  - woven step wrappers (ExaMon sensors, timers, power capping) run on the
    host around each step;
  - checkpointing is async + atomic, restart picks up the latest manifest,
    SIGTERM triggers a final checkpoint (preemption), a watchdog guards
    step deadlines, and heartbeats feed straggler detection.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.weaver import WovenProgram
from repro.data.pipeline import TokenPipeline
from repro.distributed.fault import PreemptionHandler, Watchdog
from repro.monitor.examon import ExamonBroker, get_default_broker
from repro.monitor.sensors import apply_wrappers
from repro.nn.module import init_params
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.runtime.steps import build_train_step, model_flops_per_token
from repro.versioning.libvc import LibVC


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    watchdog_deadline_s: float = 300.0
    keep_checkpoints: int = 3


class Trainer:
    def __init__(
        self,
        woven: WovenProgram,
        pipeline: TokenPipeline,
        cfg: TrainerConfig,
        *,
        mesh=None,
        opt_cfg: AdamWConfig | None = None,
        margot=None,
        broker: ExamonBroker | None = None,
        lr_fn: Callable | None = None,
    ):
        self.woven = woven
        self.pipeline = pipeline
        self.cfg = cfg
        self.mesh = mesh
        self.margot = margot
        self.broker = broker or get_default_broker()
        self.opt_cfg = opt_cfg or AdamWConfig(
            compression=bool(woven.state.extra.get("grad_compression", False)),
            state_dtype=str(woven.state.extra.get("opt_state_dtype", "float32")),
        )
        model_cfg = woven.program.cfg
        self.info: dict[str, Any] = {
            "task_name": model_cfg.name,
            "tokens_per_step": pipeline.cfg.global_batch * pipeline.cfg.seq_len,
            "flops_per_step": model_flops_per_token(model_cfg)
            * pipeline.cfg.global_batch * pipeline.cfg.seq_len,
            "knobs": dict(woven.knobs.defaults()) if len(woven.knobs) else {},
        }

        def builder(variant: str):
            step = build_train_step(self.woven, mesh=self.mesh,
                                    variant=None if variant == "__default__" else variant,
                                    opt_cfg=self.opt_cfg, lr_fn=lr_fn)
            jitted = jax.jit(step, donate_argnums=(0, 1))
            return apply_wrappers(jitted, self.woven.state.step_wrappers, self.info)

        self.libvc = LibVC(builder, error_strategy="fallback")
        self._checkpointer: Checkpointer | None = None
        self.params = None
        self.opt_state = None
        self.step = 0
        self.history: list[dict] = []
        self.restore_count = 0
        self.preemption = PreemptionHandler(install=False)
        self.watchdog_timeouts = 0

    # -- state ------------------------------------------------------------------

    def init_state(self) -> None:
        self.params = init_params(self.woven.program.model,
                                  jax.random.PRNGKey(self.cfg.seed),
                                  self.woven.state.policies)
        self.opt_state = adamw.init_state(self.params, self.opt_cfg)
        self.step = 0

    def _ckpt(self) -> Checkpointer | None:
        """One Checkpointer per trainer: its save() serializes against the
        previous async write, so overlapping saves of the same step can't
        clobber each other's tmp/final dirs."""
        if not self.cfg.ckpt_dir:
            return None
        if self._checkpointer is None:
            self._checkpointer = Checkpointer(self.cfg.ckpt_dir,
                                              keep=self.cfg.keep_checkpoints)
        return self._checkpointer

    def save(self, blocking: bool = False) -> None:
        ckpt = self._ckpt()
        if ckpt is None or self.params is None:
            return
        tree = {"params": self.params, "opt": self.opt_state,
                "data": {"step": np.asarray(self.pipeline.step)}}
        ckpt.save(self.step, tree, meta={"arch": self.woven.program.cfg.name},
                  blocking=blocking)

    def maybe_restore(self) -> bool:
        ckpt = self._ckpt()
        if ckpt is None or ckpt.latest_step() is None:
            return False
        if self.params is None:
            self.init_state()
        template = {"params": self.params, "opt": self.opt_state,
                    "data": {"step": np.asarray(0)}}
        tree, manifest = ckpt.restore(template)
        self.params = jax.tree.map(jnp.asarray, tree["params"])
        self.opt_state = jax.tree.map(jnp.asarray, tree["opt"])
        self.step = int(manifest["step"])
        self.pipeline.load_state_dict(
            {"step": int(tree["data"]["step"]), "seed": self.pipeline.cfg.seed}
        )
        self.restore_count += 1
        return True

    # -- loop -------------------------------------------------------------------

    def run(self, steps: int | None = None) -> list[dict]:
        steps = steps if steps is not None else self.cfg.steps
        if self.params is None and not self.maybe_restore():
            self.init_state()
        watchdog = Watchdog(self.cfg.watchdog_deadline_s, self._on_timeout)
        try:
            return self._run_loop(watchdog, steps)
        finally:
            # the old per-beat Timer shape left a live timer that could
            # fire after run() returned; the reused thread is disarmed and
            # joined here instead
            watchdog.close()

    def _run_loop(self, watchdog: Watchdog, steps: int) -> list[dict]:
        target = self.step + steps
        while self.step < target:
            if self.preemption.pending:
                self.save(blocking=True)
                break
            variant = None
            if self.margot is not None:
                op = self.margot.update()
                self.info["knobs"].update(op.knobs)
                variant = op.knobs.get("variant") or op.knobs.get("precision_mix")
            watchdog.beat()
            batch = jax.tree.map(jnp.asarray, next(self.pipeline))
            self.params, self.opt_state, metrics = self.libvc(
                variant, self.params, self.opt_state, batch,
                jnp.asarray(self.step, jnp.int32),
            )
            watchdog.cancel()
            self.step += 1
            host = {k: float(v) for k, v in metrics.items()
                    if jnp.ndim(v) == 0}
            host["step"] = self.step
            host["step_time"] = self.info.get("last_step_time", 0.0)
            self.history.append(host)
            self.broker.publish(
                f"fleet/heartbeat/@host{jax.process_index()}",
                host["step_time"] or 1e-4,
            )
            if self.margot is not None and host.get("step_time"):
                self.margot.observe("step_time", host["step_time"])
            if self.cfg.ckpt_every and self.step % self.cfg.ckpt_every == 0:
                self.save()
            if self.cfg.log_every and self.step % self.cfg.log_every == 0:
                print(f"step {self.step}: loss={host.get('loss', float('nan')):.4f} "
                      f"t={host['step_time']*1e3:.1f}ms")
        ckpt = self._ckpt()
        if ckpt is not None:
            ckpt.wait()
        return self.history

    def _on_timeout(self) -> None:
        self.watchdog_timeouts += 1
