"""Step builders: woven program -> pure train / prefill / decode functions.

This is where the separation of concerns pays off: the functions below read
*only* the WeaveState (policies, impls, rules, extra) — every knob the
ANTAREX aspects set lands here, and libVC compiles one executable per
variant.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.weaver import WovenProgram
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig


def _cross_entropy(logits: jax.Array, labels: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Mean token NLL + accuracy. logits (B,T,V) may cover more positions
    than labels (VLM image prefix): align to the last T_label positions."""
    T = labels.shape[1]
    logits = logits[:, -T:].astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return jnp.mean(nll), acc


def build_loss_fn(woven: WovenProgram, mesh=None, variant: str | None = None):
    program = woven.program
    state = woven.variant_state(variant)
    model = program.model

    def loss_fn(params, batch):
        ctx = state.make_ctx(mesh=mesh)
        logits, _ = model(params, batch, ctx=ctx, mode="dense")
        loss, acc = _cross_entropy(logits, batch["labels"])
        metrics = {"loss": loss, "accuracy": acc}
        metrics.update(ctx.taps)
        return loss, metrics

    return loss_fn


def build_train_step(woven: WovenProgram, *, mesh=None, variant: str | None = None,
                     opt_cfg: AdamWConfig | None = None,
                     lr_fn: Callable | None = None):
    """Returns train_step(params, opt_state, batch, step) -> (params, opt, metrics).

    Gradient accumulation (woven knob "accum_steps") scans microbatches with
    the remat policy applied inside the model's layer scan; grads accumulate
    fp32 in the params' sharding.
    """
    from repro.optim.schedule import warmup_cosine

    state = woven.variant_state(variant)
    opt_cfg = opt_cfg or AdamWConfig(
        compression=bool(state.extra.get("grad_compression", False)),
        state_dtype=str(state.extra.get("opt_state_dtype", "float32")),
    )
    lr_fn = lr_fn or warmup_cosine
    accum = int(state.extra.get("accum_steps", 1))
    loss_fn = build_loss_fn(woven, mesh, variant)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    # grad-accumulation carries must live in the params' sharding — an
    # unconstrained zeros-init would be replicated (24 GB/device fp32 for a
    # 6B model); GSPMD does not reliably back-propagate the layout.
    grad_shardings = None
    if mesh is not None:
        from repro.distributed.sharding import param_shardings

        grad_shardings = param_shardings(woven.program.model, mesh, state.rules)

    def _sharded_zeros(params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if grad_shardings is not None:
            zeros = jax.tree.map(jax.lax.with_sharding_constraint, zeros,
                                 grad_shardings)
        return zeros

    def train_step(params, opt_state, batch, step):
        if accum > 1:
            def reshape(x):
                b = x.shape[0]
                return x.reshape(accum, b // accum, *x.shape[1:])

            micro = jax.tree.map(reshape, batch)

            def body(carry, mb):
                gsum = carry
                (loss, metrics), grads = grad_fn(params, mb)
                if grad_shardings is not None:  # e.g. embed grads come back
                    grads = jax.tree.map(jax.lax.with_sharding_constraint,
                                         grads, grad_shardings)  # unsharded
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads
                )
                if grad_shardings is not None:
                    gsum = jax.tree.map(jax.lax.with_sharding_constraint,
                                        gsum, grad_shardings)
                return gsum, (loss, metrics)

            gsum, (losses, metrics) = jax.lax.scan(body, _sharded_zeros(params), micro)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
            if grad_shardings is not None:
                grads = jax.tree.map(jax.lax.with_sharding_constraint,
                                     grads, grad_shardings)

        lr = lr_fn(step)
        params, opt_state, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg, lr
        )
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def build_prefill_step(woven: WovenProgram, *, mesh=None, variant: str | None = None,
                       cache_max_len: int | None = None):
    """`cache_max_len` pins the prefill cache padding on a *copied* weave
    state — the serving probe path uses 0 (no growth room: a 1-token
    structure probe must not materialize a dense max_len cache) without
    disturbing the shared state the ordinary prefill traces read."""
    program = woven.program
    state = woven.variant_state(variant)
    if cache_max_len is not None:
        state = state.copy()
        state.extra["cache_max_len"] = cache_max_len
    model = program.model

    def prefill_step(params, inputs):
        ctx = state.make_ctx(mesh=mesh)
        logits, cache = model(params, inputs, ctx=ctx, mode="prefill")
        return logits, cache

    return prefill_step


def build_paged_prefill_step(woven: WovenProgram, *, mesh=None,
                             variant: str | None = None):
    """Prefill straight into a paged KV pool: `cache` carries the per-layer
    `{"pk", "pv"}` pools + the request's block-table row, `prefix_len`
    (static) is how many leading slots are already resident via prefix
    sharing — the model computes and scatters only the non-shared suffix,
    so admission peak HBM is O(live tokens), never O(max_len)."""
    program = woven.program
    state = woven.variant_state(variant)
    model = program.model

    def paged_prefill_step(params, inputs, cache, prefix_len: int = 0):
        ctx = state.make_ctx(mesh=mesh)
        logits, new_cache = model(params, inputs, ctx=ctx, mode="prefill",
                                  cache=cache, prefix_len=prefix_len)
        return logits, new_cache

    return paged_prefill_step


def build_decode_step(woven: WovenProgram, *, mesh=None, variant: str | None = None,
                      rescore: bool = False):
    """`rescore=True` builds the no-write decode step (paged caches only):
    a full-prompt prefix hit re-scores its last prompt token — whose K/V
    already sit on shared pool pages — for the first output logits,
    without mutating pages other requests still map."""
    program = woven.program
    state = woven.variant_state(variant)
    model = program.model

    # only paged-capable models (TransformerLM) know the re-score contract;
    # the ordinary decode step stays signature-compatible with every family
    extra_kw = {"skip_cache_write": True} if rescore else {}

    def decode_step(params, inputs, cache):
        ctx = state.make_ctx(mesh=mesh)
        logits, new_cache = model(params, inputs, ctx=ctx, mode="decode",
                                  cache=cache, **extra_kw)
        return logits, new_cache

    return decode_step


def build_verify_step(woven: WovenProgram, *, mesh=None,
                      variant: str | None = None,
                      draft_len: int | None = None):
    """Speculative-decoding verify step: one decode-mode call whose inputs
    carry a whole draft block (S = draft_len + 1 tokens per request).  The
    model's decode path returns logits for *all* S positions — row i is
    scored with draft token i attending through cache slot index + i via
    the widened-q flash_decode tile — so the host can accept the longest
    prefix where the target's argmax chain reproduces the draft.

    Structurally this is build_decode_step at S > 1; the builder exists so
    the server can pin the draft span on a *copied* weave state (the
    "speculative_draft_len" extra the tuner reads) without disturbing the
    plain decode variant's traces."""
    program = woven.program
    state = woven.variant_state(variant)
    if draft_len is not None:
        state = state.copy()
        state.extra["speculative_draft_len"] = int(draft_len)
    model = program.model

    def verify_step(params, inputs, cache):
        ctx = state.make_ctx(mesh=mesh)
        logits, new_cache = model(params, inputs, ctx=ctx, mode="decode",
                                  cache=cache)
        return logits, new_cache

    return verify_step


def stack_request_caches(model, caches: list) -> Any:
    """Stack per-request (batch=1) prefill caches into one batched decode
    cache with per-request `index` — the *dense* multi-request serving
    layout: every request pads to the same cache length, HBM scales with
    batch x max_len.  `Server.serve_continuous` replaces this with the
    paged pool (repro.runtime.pages) when the cache family supports it;
    this stays the reference layout the paged path must match bit-for-bit.

    Models that know their cache structure (TransformerLM) stack through
    their own `stack_caches`; the generic fallback concatenates every leaf
    on axis 0 (correct only for flat batch-leading caches).
    """
    if len(caches) == 1:
        return caches[0]
    if hasattr(model, "stack_caches"):
        return model.stack_caches(caches)
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *caches)


# ---------------------------------------------------------------------------
# Heuristics shared by launch + dryrun
# ---------------------------------------------------------------------------


def default_accum(cfg, shape_kind: str) -> int:
    """Microbatching needed to bound live activations/logits on 16 GB HBM
    (validated against dry-run memory_analysis; see EXPERIMENTS.md §Dry-run)."""
    if shape_kind != "train":
        return 1
    if cfg.family in ("ssm", "hybrid"):
        # dp_fsdp layout: the full global batch IS the 256/512-way DP degree;
        # microbatching would starve the mesh (per-device batch < 1)
        return 1
    n = cfg.param_count()
    if n >= 200e9:
        return 32
    if n >= 50e9:
        return 16
    return 8


def model_flops_per_token(cfg) -> float:
    """MODEL_FLOPS/token = 6·N_active (the §Roofline 'useful compute')."""
    return 6.0 * cfg.active_param_count()


def step_flops(cfg, shape) -> float:
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    f = model_flops_per_token(cfg) * tokens
    if shape.kind != "train":
        f /= 3.0  # forward only
    return f
